"""Channel-controlled compute isolation — TPU adaptation (paper §4.1).

The paper pauses offline GPU work by disabling its *channel* via a KMD ioctl
(< 1 ms, no kernel-boundary wait).  Our TPU analogue is a per-device
**dispatch gate**: the offline engine checks its gate between (sub-layer)
program dispatches and never enqueues while gated, so preemption latency is
gate-flip time + one bounded in-flight chunk.

The paper's one-line driver change removes a node-global KMD lock so multi-GPU
preemption stops scaling O(#GPUs).  We model both regimes:

- ``serial``  — every gate flip holds one node lock (the un-patched driver);
- ``fanout``  — flips are issued concurrently per device (the patched driver).

``benchmarks/preemption_latency.py`` reproduces the paper's >5 ms → <1 ms
8-GPU measurement against these two modes.
"""
from __future__ import annotations

import threading
from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass, field
from typing import List, Optional

from repro.core.clock import RealClock


@dataclass
class GateStats:
    disables: int = 0
    enables: int = 0
    last_disable_t: float = -1.0
    last_enable_t: float = -1.0


class DeviceGate:
    """Per-device dispatch gate (the channel analogue).

    ``op_latency_s`` models the per-device control-command cost (the ioctl /
    dispatch-queue round trip); 0 for pure-overhead measurements.
    """

    def __init__(self, device_id: int = 0, op_latency_s: float = 0.0,
                 clock=None):
        self.device_id = device_id
        self.op_latency_s = op_latency_s
        self.clock = clock or RealClock()
        self._enabled = threading.Event()
        self._enabled.set()
        self.stats = GateStats()

    # -- control plane ----------------------------------------------------
    # ``charge_latency=False`` lets a group-level flip charge the modeled
    # op latency once for the whole fan-out instead of per device.
    # Both flips return THIS device's measured flip latency in seconds
    # (wall time under a real clock, the charged model under a virtual
    # one) — the group folds these into the event stream.
    def disable(self, now: Optional[float] = None, *,
                charge_latency: bool = True) -> float:
        t0 = self.clock.now()
        if self.op_latency_s and charge_latency:
            self.clock.sleep(self.op_latency_s)
        self._enabled.clear()
        self.stats.disables += 1
        self.stats.last_disable_t = self.clock.now() if now is None else now
        return self.clock.now() - t0

    def enable(self, now: Optional[float] = None, *,
               charge_latency: bool = True) -> float:
        t0 = self.clock.now()
        if self.op_latency_s and charge_latency:
            self.clock.sleep(self.op_latency_s)
        self._enabled.set()
        self.stats.enables += 1
        self.stats.last_enable_t = self.clock.now() if now is None else now
        return self.clock.now() - t0

    # -- data plane (called by the offline engine between chunks) ---------
    @property
    def enabled(self) -> bool:
        return self._enabled.is_set()

    def wait_enabled(self, timeout: Optional[float] = None) -> bool:
        return self._enabled.wait(timeout)


class GateGroup:
    """Node-level gate fan-out across devices.

    mode='serial': flips issued one-by-one under a single node lock —
    preemption latency grows linearly with #devices (un-patched driver).
    mode='fanout': flips issued concurrently — latency ≈ max over devices
    (the paper's 1-line driver change).
    """

    def __init__(self, gates: List[DeviceGate], mode: str = 'fanout',
                 clock=None):
        assert mode in ('serial', 'fanout'), mode
        self.gates = gates
        self.mode = mode
        self.clock = clock or RealClock()
        self._node_lock = threading.Lock()
        # per-device flip latencies of the most recent group flip, indexed
        # like ``gates`` — the runtime folds these into PreemptionEvents
        self.last_flip_latencies: tuple = ()
        # a virtual clock charges modeled latencies synchronously — real
        # threads would race on the shared clock and record sums, not maxes
        self._pool = (ThreadPoolExecutor(max_workers=max(len(gates), 1))
                      if mode == 'fanout' and not self.clock.virtual
                      else None)

    def _apply(self, fn_name: str) -> float:
        """Flip all gates; returns elapsed seconds (the preemption latency).

        Each branch also records the MEASURED per-device flip latency in
        ``last_flip_latencies``: serial flips measure under the node lock,
        real-clock fanout measures inside each worker thread, and
        virtual-clock fanout charges every device its own modeled latency
        (the group advances the shared clock once, by the max)."""
        t0 = self.clock.now()
        if self.mode == 'serial':
            # un-patched driver: node lock serializes → Σ op latencies
            # (each gate charges its latency on the shared clock, so this
            # branch is correct under both real and virtual clocks)
            with self._node_lock:
                per = [getattr(g, fn_name)() for g in self.gates]
        elif self.clock.virtual:
            # patched driver under a virtual clock: concurrent flips →
            # max op latency, charged once for the group
            self.clock.sleep(max((g.op_latency_s for g in self.gates),
                                 default=0.0))
            per = []
            for g in self.gates:
                getattr(g, fn_name)(charge_latency=False)
                per.append(g.op_latency_s)
        else:
            futs = [self._pool.submit(getattr(g, fn_name))
                    for g in self.gates]
            per = [f.result() for f in futs]
        self.last_flip_latencies = tuple(per)
        return self.clock.now() - t0

    def disable_all(self) -> float:
        return self._apply('disable')

    def enable_all(self) -> float:
        return self._apply('enable')

    @property
    def all_disabled(self) -> bool:
        return all(not g.enabled for g in self.gates)

    def close(self):
        if self._pool is not None:
            self._pool.shutdown(wait=False)
