"""Logical-axis sharding.

Models annotate tensors with *logical* axis names; a rules context maps those
to mesh axes (flaxformer-style).  Outside a rules context every annotation is a
no-op, so the same model code runs on a single CPU device, under pjit with a
(data, model) mesh, or inside a partial-auto shard_map.

Manual-collective code (e.g. the compressed gradient all-reduce) enters
shard_map through :func:`manual_shard_map` here rather than ``jax.shard_map``
directly — the underlying API moved between jax versions, and the
compat shim in :mod:`repro.kernels.common` owns that surface.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.kernels.common import shard_map as _shard_map

AxisVal = Union[None, str, Tuple[str, ...]]

# ---------------------------------------------------------------------------
# Rule sets.  Values may name mesh axes that do not exist in the active mesh;
# missing axes are dropped at resolution time, so one rule set serves both the
# single-pod (data, model) and multi-pod (pod, data, model) meshes.
# ---------------------------------------------------------------------------

TRAIN_RULES: Dict[str, AxisVal] = {
    'batch': ('pod', 'data'),
    'seq': 'model',          # Megatron-style sequence parallelism on residuals
    'embed': None,
    'heads': 'model',
    'kv_heads': 'model',
    'head_dim': None,
    'qkv': 'model',          # fused q/k/v output dim
    'ffn': 'model',
    'vocab': 'model',
    'expert': 'model',       # expert parallelism
    'layers': None,
    'pages': None,
    'state': None,
}

# Decode/prefill: region-paged KV (per-request page regions) makes the page
# gather a batch-aligned take_along_axis, so serving shards under pure pjit —
# batch over (pod, data), tensor-parallel dims over model.
SERVE_RULES: Dict[str, AxisVal] = {
    'batch': ('pod', 'data'),
    'seq': None,
    'embed': None,
    'heads': 'model',
    'kv_heads': 'model',
    'head_dim': None,
    'qkv': 'model',
    'ffn': 'model',
    'vocab': 'model',
    'expert': 'model',
    'layers': None,
    'pages': None,
    'kv_seq': None,
    'state': None,
}

# long_500k (global_batch=1): nothing to shard on batch — the KV sequence dim
# itself is sharded over (pod, data) (sequence-parallel decode; XLA inserts the
# partial-softmax collectives).
LONG_SERVE_RULES: Dict[str, AxisVal] = dict(
    SERVE_RULES, batch=None, kv_seq=('pod', 'data'))

# ---------------------------------------------------------------------------
# §Perf hillclimb variants (see EXPERIMENTS.md §Perf for the iteration log)
# ---------------------------------------------------------------------------

# Decode H1 — contract-over-Dh: shard q AND the KV pool on head_dim (heads
# replicated).  The attention contractions then reduce over a dim that is
# sharded on BOTH operands, so XLA emits partial-score psums
# (≈ B·H·S f32 per device) instead of all-gathering the full KV
# (≈ B·S·Hkv·Dh bf16 — ~64× more wire for Dh=128/16-way).
SERVE_DH_CONTRACT_RULES: Dict[str, AxisVal] = dict(
    SERVE_RULES, heads=None, kv_heads=None, head_dim='model', qkv=None)

# Decode H2 — sequence-parallel KV: shard the page/region dim of the pool
# over the model axis; each shard attends over its local pages and XLA
# reduces the partial softmax stats + outputs (tiny collectives).
SERVE_SEQ_RULES: Dict[str, AxisVal] = dict(
    SERVE_RULES, pages='model', kv_seq='model')

# Decode H3 — data-parallel attention: the KV pool replicates over the model
# axis (batch stays on data); attention is collective-free and the model
# axis serves only the projections/MLP/vocab.  Costs HBM capacity
# (replicated KV) — viable when B/|data| × S × KV-bytes fits.
SERVE_KV_DP_RULES: Dict[str, AxisVal] = dict(
    SERVE_RULES, heads=None, kv_heads=None, head_dim=None)

# Train H1 — no sequence parallelism on the residual stream: trades the
# per-layer-boundary all-gather/reduce-scatter pairs for replicated
# activations (more HBM, less wire).
TRAIN_NO_SP_RULES: Dict[str, AxisVal] = dict(TRAIN_RULES, seq=None)

RULE_VARIANTS = {
    'default': None,                      # resolved per shape kind
    'serve_dh': SERVE_DH_CONTRACT_RULES,
    'serve_seq': SERVE_SEQ_RULES,
    'serve_kv_dp': SERVE_KV_DP_RULES,
    'train_no_sp': TRAIN_NO_SP_RULES,
}

_tls = threading.local()


def _current() -> Optional[Tuple[Mesh, Dict[str, AxisVal]]]:
    return getattr(_tls, 'ctx', None)


@contextlib.contextmanager
def axis_rules(mesh: Optional[Mesh], rules: Dict[str, AxisVal]):
    prev = _current()
    _tls.ctx = (mesh, rules) if mesh is not None else None
    try:
        yield
    finally:
        _tls.ctx = prev


def logical_to_spec(axes: Sequence[Optional[str]],
                    rules: Dict[str, AxisVal],
                    mesh: Optional[Mesh] = None) -> P:
    """Map logical axis names to a PartitionSpec, dropping absent mesh axes.

    A rule whose mapped axes are *all* absent from the mesh resolves to
    ``None`` (replicated) — never a stale name tuple.  ``mesh=None`` has no
    axes at all, so every mapping degrades to replicated; the old behavior
    (pass the rule tuple through unfiltered) produced specs naming axes no
    mesh provides, which ``NamedSharding`` rejects.
    """
    mesh_axes = set(mesh.axis_names) if mesh is not None else set()
    used: set = set()
    parts = []
    for ax in axes:
        val = rules.get(ax) if ax is not None else None
        if val is None:
            parts.append(None)
            continue
        val_t = (val,) if isinstance(val, str) else tuple(val)
        val_t = tuple(v for v in val_t if v in mesh_axes)
        val_t = tuple(v for v in val_t if v not in used)
        used.update(val_t)
        if not val_t:
            parts.append(None)
        elif len(val_t) == 1:
            parts.append(val_t[0])
        else:
            parts.append(val_t)
    while parts and parts[-1] is None:
        parts.pop()
    return P(*parts)


def manual_shard_map(fn, mesh: Mesh, in_specs, out_specs, *,
                     check_replication: bool = False):
    """Version-portable ``shard_map`` entry for manual-collective code.

    ``check_replication=False`` matches the historical ``check_rep=False`` /
    ``check_vma=False`` default our collectives rely on (psum of int8
    payloads is replication-breaking by design).
    """
    return _shard_map(fn, mesh, in_specs=in_specs, out_specs=out_specs,
                      check_replication=check_replication)


def constrain(x, axes: Sequence[Optional[str]]):
    """with_sharding_constraint by logical axes; no-op outside a rules context."""
    ctx = _current()
    if ctx is None:
        return x
    mesh, rules = ctx
    spec = logical_to_spec(axes, rules, mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def shaped_spec(shape: Sequence[int], axes: Sequence[Optional[str]],
                rules: Dict[str, AxisVal], mesh: Mesh) -> P:
    """Shape-aware resolution for jit *arguments* (which must divide evenly,
    unlike intermediates).

    Mesh axes whose size does not divide the mapped dimension are dropped
    from that dimension and re-placed on the last unsharded, divisible
    dimension instead (e.g. 8 KV heads can't shard over model=16 → the
    model axis moves to head_dim=128).  Deterministic, so lowering and
    restore agree.
    """
    sizes = dict(mesh.shape)
    used: set = set()
    groups: list = []
    freed: list = []
    for dim, ax in zip(shape, axes):
        val = rules.get(ax) if ax is not None else None
        if val is None:
            groups.append([])
            continue
        val_t = (val,) if isinstance(val, str) else tuple(val)
        val_t = [v for v in val_t if v in sizes and v not in used]
        # drop trailing axes until the product divides the dim
        while val_t:
            prod = 1
            for v in val_t:
                prod *= sizes[v]
            if dim % prod == 0:
                break
            freed.append(val_t.pop())
        used.update(val_t)
        groups.append(list(val_t))
    # re-place freed axes on the last divisible unsharded dims
    for v in freed:
        for i in range(len(groups) - 1, -1, -1):
            if not groups[i] and shape[i] % sizes[v] == 0 and shape[i] > 1:
                groups[i].append(v)
                used.add(v)
                break
    parts = [None if not g else (g[0] if len(g) == 1 else tuple(g))
             for g in groups]
    while parts and parts[-1] is None:
        parts.pop()
    return P(*parts)


def spec_for(axes: Sequence[Optional[str]],
             rules: Dict[str, AxisVal],
             mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, logical_to_spec(axes, rules, mesh))


def tree_spec(logical_tree, rules: Dict[str, AxisVal], mesh: Mesh):
    """Map a pytree of logical-axis tuples to NamedShardings."""
    return jax.tree.map(
        lambda axes: spec_for(axes, rules, mesh),
        logical_tree,
        is_leaf=lambda t: isinstance(t, tuple) and all(
            a is None or isinstance(a, str) for a in t),
    )


def _is_axes_leaf(t):
    return isinstance(t, tuple) and all(
        a is None or isinstance(a, str) for a in t)


def tree_spec_shaped(logical_tree, shapes_tree, rules: Dict[str, AxisVal],
                     mesh: Mesh):
    """Shape-aware tree_spec for jit argument shardings."""
    flat_axes, tdef = jax.tree.flatten(logical_tree, is_leaf=_is_axes_leaf)
    flat_shapes = tdef.flatten_up_to(shapes_tree)
    out = [NamedSharding(mesh, shaped_spec(tuple(s.shape), a, rules, mesh))
           for a, s in zip(flat_axes, flat_shapes)]
    return jax.tree.unflatten(tdef, out)
