"""Train a ~100M-parameter qwen3-family model end-to-end on the full
substrate: synthetic n-gram data with prefetch, AdamW + cosine schedule,
remat scan-over-layers, atomic checkpoints with restart.

Default runs a scaled-down (~10M) config so CPU finishes in minutes; pass
--full for the ~100M layout (d_model 640, 12 layers, vocab 32k — the same
code lowers unchanged on the production mesh via launch/dryrun.py).

    PYTHONPATH=src python examples/train_100m.py --steps 200
"""
import argparse

from repro.launch.train import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument('--steps', type=int, default=200)
    ap.add_argument('--full', action='store_true',
                    help='~100M params instead of the CPU-sized ~10M')
    ap.add_argument('--ckpt-dir', default='/tmp/valve_train_100m')
    ap.add_argument('--restore', action='store_true')
    args = ap.parse_args()

    if args.full:
        overrides = dict(n_layers=12, d_model=640, n_heads=10, n_kv_heads=2,
                         d_ff=2560, vocab_size=32_768, head_dim=64)
        batch, seq = 8, 256
    else:
        overrides = dict(n_layers=6, d_model=256, n_heads=8, n_kv_heads=4,
                         d_ff=1024, vocab_size=8_192, head_dim=32)
        batch, seq = 8, 128

    from repro.configs import get_config, reduced
    cfg = reduced(get_config('qwen3-0.6b'), **overrides)
    n = cfg.param_count()
    print(f'model: {n / 1e6:.1f}M params '
          f'({cfg.n_layers}L d={cfg.d_model} ff={cfg.d_ff} '
          f'V={cfg.vocab_size})')

    _, _, losses = train(
        'qwen3-0.6b', steps=args.steps, batch=batch, seq=seq,
        use_reduced=True, reduced_overrides=overrides,
        ckpt_dir=args.ckpt_dir, ckpt_every=50, restore=args.restore,
        log_every=10)
    print(f'loss: {losses[0]:.3f} → {losses[-1]:.3f} '
          f'over {len(losses)} steps')


if __name__ == '__main__':
    main()
