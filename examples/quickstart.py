"""Quickstart: the Valve colocation runtime in ~60 lines.

Builds a reduced LM, shares one paged KV pool between an ONLINE and an
OFFLINE engine through the ValveRuntime, and demonstrates the paper's three
guarantees on a live run:

1. offline compute is gated during online request lifetimes (≤1 preemption
   per online request, wake after T_cool);
2. online memory pressure reclaims offline KV pages safely (quarantine
   remap, no faults, no kills);
3. invalidated offline requests recompute and finish with IDENTICAL output
   (greedy decoding is deterministic).

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import numpy as np

from repro.configs import get_config, reduced
from repro.core.clock import VirtualClock
from repro.core.runtime import RuntimeConfig, ValveRuntime
from repro.models.api import build_model
from repro.serving.engine import Engine, EngineConfig
from repro.serving.kvpool import KVPool


def main():
    rng = np.random.default_rng(0)
    cfg = reduced(get_config('qwen3-0.6b'), page_size=4)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))

    # reservation starts at one 8-page handle: the online burst (9 pages)
    # overflows it, forcing the compute-first reclamation path.  The pool is
    # sized so every offline handle holds live pages — the reclaimed handle
    # must invalidate offline requests, exercising the recompute contract
    pool = KVPool(n_handles=4, pages_per_handle=8, page_size=4,
                  reserved_handles=1)
    clock = VirtualClock()

    # no callback wiring needed: the runtime fans invalidations out to the
    # engine owning each request (engines bind at submit time)
    rt = ValveRuntime(pool, RuntimeConfig(n_devices=1), clock=clock)
    online = Engine(model, params, pool,
                    EngineConfig(max_batch=4, max_seq=64, prefill_chunk=16,
                                 klass='online'), runtime=rt, clock=clock)
    offline = Engine(model, params, pool,
                     EngineConfig(max_batch=4, max_seq=64, prefill_chunk=16,
                                  klass='offline'), runtime=rt, clock=clock)

    # an offline backlog; run it undisturbed first to get reference outputs
    prompts = [rng.integers(1, cfg.vocab_size, 12).tolist() for _ in range(3)]
    refs = {}
    for p in prompts:
        rid = offline.submit(p, max_new_tokens=10)
        refs[rid] = p
    offline.run_to_completion()
    reference = {r: offline.output_tokens(r) for r in refs}
    print(f'offline reference outputs computed '
          f'({offline.stats.tokens_generated} tokens)')

    # fresh run, now with online interference mid-flight
    offline2 = Engine(model, params, pool,
                      EngineConfig(max_batch=4, max_seq=64, prefill_chunk=16,
                                   klass='offline'), runtime=rt, clock=clock)
    rids = [offline2.submit(p, max_new_tokens=10) for p in prompts]
    # a few steps only: the batched scheduler prefills all three requests in
    # one mixed dispatch, so they are mid-generation when the burst arrives
    for _ in range(4):
        offline2.step()

    # online burst arrives: gates close, memory reclaimed from offline
    print('\n>>> online burst')
    on_rid = online.submit(rng.integers(1, cfg.vocab_size, 24).tolist(),
                           max_new_tokens=12)
    online.run_to_completion()
    print(f'online finished: {len(online.output_tokens(on_rid))} tokens, '
          f'preemptions={rt.stats.compute_preemptions}, '
          f'reclamations={rt.reclaimer.stats.reclamations}, '
          f'offline requests invalidated={offline2.stats.invalidations}')

    # offline wakes after T_cool and recomputes to the same outputs
    clock.advance(rt.lifecycle.t_cool + 1e-3)
    rt.tick()
    offline2.run_to_completion()
    ok = all(offline2.output_tokens(r) == reference[r0]
             for r, r0 in zip(rids, refs))
    print(f'\noffline recompute exact: {ok}')
    rt.check_invariants()
    print('invariants hold: compute-first ordering, ≤1 preemption/request, '
          'no page faults, no kills')


if __name__ == '__main__':
    main()
