"""End-to-end colocation serving driver (the paper's deployment scenario).

Drives the live online+offline engines under bursty synthetic traffic and
reports the paper's metrics: online TTFT/TPOT, offline tokens/s, preemption
and reclamation counts, and the ≤1-preemption-per-request bound.

    PYTHONPATH=src python examples/colocation_demo.py --steps 600
"""
import argparse

from repro.launch.serve import serve_demo


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument('--arch', default='qwen3-0.6b')
    ap.add_argument('--steps', type=int, default=600)
    ap.add_argument('--online-rate', type=float, default=0.03)
    ap.add_argument('--seed', type=int, default=0)
    args = ap.parse_args()
    print(f'colocating online+offline {args.arch} (reduced) for '
          f'{args.steps} scheduler ticks…')
    m = serve_demo(arch=args.arch, steps=args.steps,
                   online_rate=args.online_rate, seed=args.seed)
    assert m['max_preemptions_per_request'] <= 1
    print('\nValve bound holds: at most one preemption per online request.')


if __name__ == '__main__':
    main()
