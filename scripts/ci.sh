#!/usr/bin/env bash
# CI gate: tier-1 suite + a fast kernel-parity subset.
#
# The kernel-parity subset re-runs first and verbosely even though tier-1
# includes it: the Pallas kernels are where jax API drift lands (compiler
# params, shard_map, cost_analysis — all shimmed in
# src/repro/kernels/common.py), so a jax bump that breaks them fails loudly
# at the top of the log instead of somewhere inside the full run.
#
# Usage:  scripts/ci.sh [--kernels-only|--regen-api]
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
export JAX_PLATFORMS="${JAX_PLATFORMS:-cpu}"

if [[ "${1:-}" == "--regen-api" ]]; then
    # deliberate public-API change: refresh the pinned snapshot
    python -m repro.core.api > tests/api_surface.txt
    echo "regenerated tests/api_surface.txt ($(wc -l < tests/api_surface.txt) lines)"
    exit 0
fi

echo "== jax version: $(python -c 'import jax; print(jax.__version__)')"

echo "== valve patch surface =="
# single source of truth for the counts lives in tests/test_patch_surface.py
python - <<'PY'
import sys
sys.path.insert(0, 'tests')
from test_patch_surface import patch_loc, session_patch_loc
loc, sloc = patch_loc(), session_patch_loc()
print(f'framework-side patch: {loc} LOC (paper Table 1 contract: < 20; '
      f'memory-plane v1 budget: <= 13)')
print(f'session-API integration: {sloc} tagged lines (open/mint/admit/'
      f'finish/gate/notify)')
assert 0 < loc <= 13, loc   # surviving-prefix resume must not bloat it
assert 0 < sloc < 10, sloc
PY

echo "== memory-plane lease property smoke (fast gate) =="
python -m pytest -q tests/test_memory.py

echo "== control-plane API surface (pinned snapshot) =="
python - <<'PY'
from repro.core.api import api_surface
want = open('tests/api_surface.txt').read().splitlines()
got = api_surface()
assert got == want, ('public API drifted from tests/api_surface.txt — '
                     'if intentional, run scripts/ci.sh --regen-api')
print(f'API surface matches snapshot ({len(got)} lines)')
PY

echo "== node demo smoke (heterogeneous colocation) =="
python -m repro.launch.serve --steps 50

echo "== serving front-end: SSE conformance (fast gate) =="
python -m pytest -q tests/test_sse.py

echo "== serving front-end: in-process HTTP smoke (1 stream + 1 batch, no sockets) =="
python - <<'PY'
import asyncio, json
from repro.configs import get_config, reduced
from repro.core.clock import VirtualClock
from repro.core.runtime import RuntimeConfig, ValveRuntime
from repro.launch.node import NodeOrchestrator
from repro.serving.engine import EngineConfig
from repro.serving.frontend.app import FrontendApp
from repro.serving.frontend.driver import AsyncNodeDriver, clock_sleep
from repro.serving.frontend.testing import ASGIClient
from repro.serving.kvpool import KVPool

pool = KVPool(6, 4, page_size=4, reserved_handles=1)
rt = ValveRuntime(pool, RuntimeConfig(n_devices=1, t_cool_init=0.002),
                  clock=VirtualClock())
node = NodeOrchestrator(rt, idle_advance=1e-3)
for klass, seed in (('online', 0), ('offline', 1)):
    node.add_engine(reduced(get_config('qwen3-0.6b'), page_size=4),
                    EngineConfig(max_batch=4, max_seq=48, prefill_chunk=8,
                                 klass=klass), seed=seed)

async def main():
    async with AsyncNodeDriver(node) as driver:
        client = ASGIClient(FrontendApp(driver))
        sr = client.stream('POST', '/v1/completions',
                           json={'prompt': [5, 7, 11], 'max_tokens': 4,
                                 'stream': True})
        toks = 0
        async with sr:
            assert sr.status == 200, sr.status
            async for ev in sr.events():
                if ev.done:
                    break
                if json.loads(ev.data)['choices'][0].get('token') is not None:
                    toks += 1
        assert toks == 4, toks
        job = (await client.post('/v1/batches', json={
            'requests': [{'prompt': [3, 1, 4], 'max_tokens': 3}]})).json()
        for _ in range(20000):
            st = (await client.get(f"/v1/batches/{job['id']}")).json()['status']
            if st == 'completed':
                break
            await clock_sleep(node.clock, 1e-4)
        assert st == 'completed', st
        res = (await client.get(f"/v1/batches/{job['id']}/results")).json()
        assert len(res['results'][0]['tokens']) == 3, res

asyncio.run(main())
node.runtime.check_invariants()
assert node.runtime.invalidation_routes() == []
print('front-end smoke OK: 1 SSE stream (4 tokens) + 1 batch job, in-process')
PY

echo "== rate-estimator warm-up regressions (fast gate) =="
python -m pytest -q tests/test_rate_estimators.py

echo "== cluster-harness smoke (small fleet, short horizon) =="
python - <<'PY'
from repro.core.cluster.harness import HarnessConfig, make_harness
from repro.core.sim.colocation import SimConfig

cfg = HarnessConfig(n_nodes=3, gpus_per_node=2, epoch_s=20.0, n_epochs=2,
                    sim=SimConfig(total_pages=1024), measure_baseline=False)
h = make_harness(cfg)
h.run()
assert h.scheduler.placements, 'smoke fleet placed no offline jobs'
assert all(g.source == 'nodesim'
           for t in h.scheduler.nodes.values() for g in t.gpus), \
    'scheduler consumed non-measured telemetry'
print(f'cluster smoke OK: {len(h.scheduler.placements)} jobs placed, '
      f'util {h.reports[-1].utilization_gain_measured:.1%}')
PY

echo "== kernel parity (fast subset, interpret mode) =="
python -m pytest -q \
    tests/test_kernels_flash.py \
    tests/test_kernels_paged.py \
    tests/test_kernels_sampling.py \
    tests/test_kernels_rwkv6.py \
    tests/test_kernel_integration.py

if [[ "${1:-}" == "--kernels-only" ]]; then
    exit 0
fi

echo "== kernel hot-path smoke (fused decode regression gate) =="
python benchmarks/kernel_hotpath.py --smoke

echo "== shard-scale smoke (mesh parity + zero-recompute rescue gate) =="
python benchmarks/shard_scale.py --smoke

echo "== disagg smoke (2-pool handoff: bit-identity + zero-recompute gate) =="
python benchmarks/disagg.py --smoke

echo "== fleet-placement smoke (global ≥ greedy + vectorized-sim gate) =="
python benchmarks/fleet_placement.py --smoke

echo "== tier-1 =="
python -m pytest -x -q

echo "CI green."
