"""Fleet placement plane: global optimizer vs greedy Eq. 1 on a
heterogeneous 100+-node cluster, plus the vectorized-NodeSim gate that
keeps the sweep inside CI budget.

Three hard gates (raise on failure — this benchmark is wired into CI as
``--smoke``):

1. **vectorized NodeSim** — ``SimConfig(vectorized=True)`` must be ≥ 3×
   faster than the scalar event loop on the decode-heavy gate scenario
   AND produce bit-identical ``SimResult`` telemetry (every latency,
   token count, busy interval, memory sample, and typed event);
2. **global ≥ greedy** — on the *identical* scout telemetry (same fleet,
   same seed, every Eq. 1 input ``source='nodesim'``), the global
   optimizer's predicted utilization gain at submission must be ≥ the
   greedy baseline's, and its solver wall time must fit the budget;
3. **colocation invariants ride along** — ≤ 1 compute preemption per
   online request on every GPU-epoch of the closed loop, and the
   framework-side patch stays ≤ 13 LOC (imported from
   tests/test_patch_surface.py, the single source of truth).

The fleet mixes A100/L4/T4 nodes (``placement.profiles.GPU_CATALOG``):
slow cards *run* slower sims (``GPUProfile.scale_sim``) and the catalog
scalar re-enters Eq. 1, so predictions and measurements stay in the same
normalized units.

Writes ``results/fleet_placement.json`` and mirrors to
``BENCH_fleet.json`` at the repo root.
"""
from __future__ import annotations

import json
import os
import sys
import time
from dataclasses import replace
from typing import Dict

import numpy as np

from repro.core.cluster.harness import HarnessConfig, make_harness
from repro.core.sim.colocation import SimConfig, run_strategy
from repro.core.sim.workload import (
    OfflineWorkload, WorkloadPair, make_online_trace)

GPU_MIX = (('A100', 0.3), ('L4', 0.4), ('T4', 0.3))


# ---------------------------------------------------------------------------
# Gate 1: vectorized NodeSim — bit-identical and ≥ 3× on the gate scenario
# ---------------------------------------------------------------------------

def _sim_signature(res) -> Dict:
    tel = res.telemetry.counters
    return dict(
        ttft=res.ttft, tpot=res.tpot, off=res.offline_tokens,
        wasted=res.offline_tokens_wasted, rec=res.recompute_tokens,
        busy=res.busy_intervals, mt=res.mem_trace_t, mf=res.mem_trace_free,
        rej=res.rejected, mp=res.max_preempt_per_request,
        ev=[repr(e) for e in res.events],
        tel={k: getattr(tel, k) for k in dir(tel) if not k.startswith('_')
             and isinstance(getattr(tel, k), (int, float))})


def gate_vectorized(horizon_s: float = 600.0, min_speedup: float = 3.0
                    ) -> Dict:
    """Decode-heavy colocation (long offline outputs, batch-capped, sparse
    online) — the stretch the batched fast path exists for."""
    off = OfflineWorkload('long', prompt_tokens=256, output_tokens=2048,
                          max_batch=24)
    on = make_online_trace(name='sparse', horizon_s=horizon_s,
                           base_rate=0.02, burst_rate=0.5, seed=11)
    pair = WorkloadPair('gate', on, off)
    cfg = SimConfig(total_pages=8192)

    t0 = time.perf_counter()
    scalar = run_strategy(pair, 'Channel', 'OurMem', cfg)
    t_scalar = time.perf_counter() - t0
    t0 = time.perf_counter()
    vec = run_strategy(pair, 'Channel', 'OurMem',
                       replace(cfg, vectorized=True))
    t_vec = time.perf_counter() - t0

    sa, sb = _sim_signature(scalar), _sim_signature(vec)
    for k in sa:
        assert sa[k] == sb[k], \
            f'vectorized NodeSim diverges from scalar in {k!r}'
    speedup = t_scalar / max(t_vec, 1e-9)
    assert speedup >= min_speedup, \
        f'vectorized speedup {speedup:.2f}x < required {min_speedup}x'
    print(f'vectorized NodeSim: {speedup:.1f}x ({t_scalar:.2f}s -> '
          f'{t_vec:.2f}s), telemetry bit-identical')
    return {'scalar_s': t_scalar, 'vectorized_s': t_vec,
            'speedup': speedup, 'bit_identical': True,
            'min_speedup_gate': min_speedup}


# ---------------------------------------------------------------------------
# Gate 2+3: the heterogeneous fleet sweep, one run per policy
# ---------------------------------------------------------------------------

def run_policy_fleet(policy: str, *, n_nodes: int, gpus_per_node: int,
                     epoch_s: float, n_epochs: int, seed: int,
                     n_jobs: int, measure_baseline: bool) -> Dict:
    cfg = HarnessConfig(
        n_nodes=n_nodes, gpus_per_node=gpus_per_node, epoch_s=epoch_s,
        n_epochs=n_epochs, seed=seed, placement=policy, gpu_mix=GPU_MIX,
        sim=SimConfig(total_pages=1024, vectorized=True),
        measure_baseline=measure_baseline)
    h = make_harness(cfg, n_jobs=n_jobs)
    t0 = time.perf_counter()
    h.scout()
    # identical measured telemetry on both sides of the comparison: the
    # scout sims are seeded by the fleet alone, never by the policy
    for tele in h.scheduler.nodes.values():
        for g in tele.gpus:
            assert g.source == 'nodesim', (tele.name, g.source)
    h.submit_all()
    util_pred = h.scheduler.utilization_gain(measured=False)
    for e in range(1, n_epochs + 1):
        h.run_epoch(e)
    wall = time.perf_counter() - t0

    reports = h.reports
    ttft = [r.ttft_delta for r in reports if r.ttft_delta is not None]
    solver_s = sum(r.solver_wall_s for r in reports)
    solve = None
    rep = getattr(h.scheduler.policy, 'last_report', None)
    if rep is not None:
        solve = {'jobs': rep.jobs, 'candidates': rep.candidates,
                 'pruned': rep.pruned, 'warm_start_value':
                 rep.warm_start_value, 'value': rep.value,
                 'rounds': rep.rounds, 'method': rep.method,
                 'wall_time_s': rep.wall_time_s}
        solver_s += sum(r.wall_time_s
                        for r in h.scheduler.policy.reports)
    max_preempt = max(r.max_preempt_per_request for r in reports)
    assert max_preempt <= 1, \
        f'{policy}: {max_preempt} compute preemptions on one request'
    return {
        'policy': policy,
        'jobs_submitted': n_jobs,
        'jobs_placed_final': len(h.scheduler.placements),
        'jobs_pending_final': len(h.scheduler.pending),
        'utilization_gain_predicted_submit': util_pred,
        'utilization_gain_final': reports[-1].utilization_gain_measured,
        'utilization_gain_mean': float(np.mean(
            [r.utilization_gain_measured for r in reports])),
        'gpus_saved_final': reports[-1].gpus_saved_measured,
        'evictions': h.scheduler.evictions,
        'reschedules': h.scheduler.reschedules,
        'ttft_delta_mean': float(np.mean(ttft)) if ttft else None,
        'max_preempt_per_request': max_preempt,
        'solver_wall_s': solver_s,
        'harness_wall_s': wall,
        'last_solve': solve,
    }


def run(out_path: str = 'results/fleet_placement.json', *,
        n_nodes: int = 100, gpus_per_node: int = 2, epoch_s: float = 30.0,
        n_epochs: int = 2, seed: int = 0, n_jobs: int = 60,
        measure_baseline: bool = True, solver_budget_s: float = 5.0,
        vec_horizon_s: float = 600.0, mirror: bool = True) -> Dict:
    vec = gate_vectorized(horizon_s=vec_horizon_s)

    rows = {}
    for policy in ('greedy-eq1', 'global-opt'):
        rows[policy] = run_policy_fleet(
            policy, n_nodes=n_nodes, gpus_per_node=gpus_per_node,
            epoch_s=epoch_s, n_epochs=n_epochs, seed=seed, n_jobs=n_jobs,
            measure_baseline=measure_baseline)
        r = rows[policy]
        pct = lambda v: f'{v:+.1%}' if v is not None else 'n/a'
        print(f'{policy:>11}: predicted util {r["utilization_gain_predicted_submit"]:.3f}, '
              f'measured {r["utilization_gain_final"]:.3f} final '
              f'({r["utilization_gain_mean"]:.3f} mean), '
              f'placed {r["jobs_placed_final"]}/{n_jobs}, '
              f'TTFT Δ {pct(r["ttft_delta_mean"])}, '
              f'solver {r["solver_wall_s"]*1e3:.1f}ms, '
              f'harness {r["harness_wall_s"]:.1f}s')

    greedy, glob = rows['greedy-eq1'], rows['global-opt']
    # THE gate: same fleet, same measured scout telemetry — the global
    # solve must match or beat greedy's predicted objective
    assert (glob['utilization_gain_predicted_submit']
            >= greedy['utilization_gain_predicted_submit'] - 1e-9), \
        'global optimizer scored below the greedy baseline'
    assert glob['solver_wall_s'] <= solver_budget_s, \
        f'solver {glob["solver_wall_s"]:.2f}s over {solver_budget_s}s budget'

    # patch-surface invariant rides along (single source of truth)
    sys.path.insert(0, os.path.join(os.path.dirname(__file__) or '.',
                                    '..', 'tests'))
    from test_patch_surface import patch_loc
    loc = patch_loc()
    assert 0 < loc <= 13, f'framework patch grew to {loc} LOC'

    result = {
        'fleet': {'nodes': n_nodes, 'gpus_per_node': gpus_per_node,
                  'epoch_s': epoch_s, 'epochs': n_epochs, 'seed': seed,
                  'gpu_mix': [list(m) for m in GPU_MIX],
                  'jobs': n_jobs},
        'vectorized_gate': vec,
        'policies': rows,
        'gates': {
            'global_ge_greedy_predicted_util': True,
            'vectorized_speedup_ge': vec['min_speedup_gate'],
            'solver_budget_s': solver_budget_s,
            'max_preempt_per_request_le_1': True,
            'framework_patch_loc': loc,
        },
    }
    os.makedirs(os.path.dirname(out_path) or '.', exist_ok=True)
    with open(out_path, 'w') as f:
        json.dump(result, f, indent=1)
    if mirror:
        with open('BENCH_fleet.json', 'w') as f:
            json.dump(result, f, indent=1)
    gain = (glob['utilization_gain_predicted_submit']
            - greedy['utilization_gain_predicted_submit'])
    print(f'global vs greedy on {n_nodes} heterogeneous nodes: '
          f'+{gain:.4f} predicted util '
          f'({glob["jobs_placed_final"]} vs {greedy["jobs_placed_final"]} '
          f'jobs placed); all gates passed')
    return result


def run_smoke() -> Dict:
    """CI smoke: 12-node mixed fleet, same hard gates, seconds not
    minutes.  Does not overwrite the full-sweep BENCH_fleet.json mirror."""
    return run('results/fleet_placement_smoke.json', n_nodes=12,
               epoch_s=20.0, n_epochs=2, n_jobs=10, measure_baseline=True,
               solver_budget_s=2.0, vec_horizon_s=300.0, mirror=False)


if __name__ == '__main__':
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument('--smoke', action='store_true',
                    help='12-node mixed fleet (CI gate)')
    ap.add_argument('--nodes', type=int, default=100)
    ap.add_argument('--epochs', type=int, default=2)
    ap.add_argument('--jobs', type=int, default=60)
    ap.add_argument('--seed', type=int, default=0)
    a = ap.parse_args()
    if a.smoke:
        run_smoke()
    else:
        run(n_nodes=a.nodes, n_epochs=a.epochs, n_jobs=a.jobs, seed=a.seed)
