"""Kernel hot-path latency — the decode-step speed trajectory (ROADMAP 3).

Not a paper figure: this benchmark pins the repo's own hot-path claims the
way ``api_overhead``/``serve_throughput`` pin theirs.  Valve's preemption
window is bounded by engine iteration latency (the gate flips *between*
dispatches), so µs/decode-step is a correctness-adjacent number, not just a
throughput one.

Three engine configurations drain the same decode-heavy workload:

1. **baseline** — logits returned per step, host-side argmax
   (``np.asarray`` device→host sync every iteration);
2. **fused** — ``EngineConfig.fused_sampling``: the unembed+argmax runs
   inside the dispatch (logits never round-trip to HBM), sampled tokens
   stay on device between iterations and resolve lazily;
3. **fused+shared** — additionally ``prefix_shared_attention``: CoW-shared
   prefix pages are deduplicated per batch (each physical page read once
   per batch instead of once per request).

Greedy outputs are asserted identical across all three.  A session
alloc/free micro (the memory-plane fast path) rides along so the three
numbers the ROADMAP names — step µs, tokens/s, alloc µs — live in one
trajectory file.

Writes ``results/kernel_hotpath.json`` and mirrors it to
``BENCH_kernels.json`` at the repo root.  ``--smoke`` is the CI gate: the
committed trajectory must still claim a real fused win, and a quick live
baseline-vs-fused re-measure (same window, so machine speed and window
length self-calibrate) must keep the speedup above a floor.
"""
from __future__ import annotations

import json
import os
import time
from typing import Dict, List

import numpy as np

SMOKE_GATE = 0.10          # committed fused speedup must stay > 1 + gate
SMOKE_MIN_SPEEDUP = 1.10   # live short-window fused-vs-baseline floor


def _build_engine(fused: bool, shared: bool, *, seed: int = 0):
    import jax
    from repro.configs import get_config, reduced
    from repro.models.api import build_model
    from repro.serving.engine import Engine, EngineConfig
    from repro.serving.kvpool import KVPool

    cfg = reduced(get_config('qwen3-0.6b'), page_size=4)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(seed))
    pool = KVPool(n_handles=40, pages_per_handle=4, page_size=4,
                  reserved_handles=1)
    ecfg = EngineConfig(max_batch=4, max_seq=160, prefill_chunk=16,
                        klass='offline', fused_sampling=fused,
                        prefix_shared_attention=shared)
    return Engine(model, params, pool, ecfg), cfg


def _measure_decode(fused: bool, shared: bool, *, warm: int, steps: int,
                    gen: int, seed: int = 0) -> Dict:
    """Steady-state decode: ``warm`` unmeasured iterations (covers jit
    compilation of every dispatch shape), then ``steps`` timed ones with
    the full batch still running.  The fused path's lazy-token flush is
    timed inside the window (one sync amortized over the window, exactly
    the serving shape)."""
    eng, cfg = _build_engine(fused, shared, seed=seed)
    rng = np.random.default_rng(seed)
    # one common prompt: submitted FIRST and prefilled alone so its prefix
    # pages publish; the followers attach them copy-on-write — that gives
    # the prefix-shared kernel real shared runs to deduplicate
    prompt = rng.integers(1, cfg.vocab_size, 24).tolist()
    rids = [eng.submit(prompt, max_new_tokens=gen)]
    for _ in range(40):
        eng.step()
        if eng.requests[rids[0]].generated:
            break                              # r0 prefilled + published
    rids += [eng.submit(prompt, max_new_tokens=gen) for _ in range(3)]
    # warm until the whole batch is past prefill and ``warm`` decode
    # iterations have run (covers jit compilation of every dispatch shape)
    while (eng.queue
           or any(not eng.requests[r].generated for r in rids)
           or eng.stats.decode_iterations < warm):
        if not eng.step():
            break
    t0 = time.perf_counter()
    for _ in range(steps):
        eng.step()
    eng.flush_tokens()
    wall = time.perf_counter() - t0
    eng.run_to_completion()
    outs = [eng.output_tokens(r) for r in rids]
    us_step = wall / steps * 1e6
    return {
        'us_per_decode_step': us_step,
        'decode_tokens_per_s': eng.cfg.max_batch / wall * steps,
        'shared_page_reads_saved': eng.stats.shared_page_reads_saved,
        'token_flushes': eng.stats.token_flushes,
        '_outputs': outs,
    }


def _alloc_micro(n: int = 20_000) -> Dict[str, float]:
    """Session alloc/free µs (the memory-plane fast path) — the third
    ROADMAP-named hot-path number, in the same trajectory file."""
    from repro.core.clock import VirtualClock
    from repro.core.runtime import RuntimeConfig, ValveRuntime
    from repro.serving.kvpool import KVPool

    pool = KVPool(8, 8, reserved_handles=1)
    rt = ValveRuntime(KVPool(8, 8, reserved_handles=1), RuntimeConfig(),
                      clock=VirtualClock())
    sess = rt.open_session('offline', name='hotpath')

    def timed(fn) -> float:
        best = float('inf')
        for _ in range(5):
            t0 = time.perf_counter()
            for _ in range(n):
                fn()
            best = min(best, time.perf_counter() - t0)
        return best / n * 1e6

    def pool_af():
        pool.alloc('r', 2, klass='offline')
        pool.free('r')

    def sess_af():
        sess.alloc('r', 2)
        sess.free('r')

    out = {'pool_alloc_free_us': timed(pool_af),
           'session_alloc_free_us': timed(sess_af)}
    out['session_alloc_overhead_x'] = (out['session_alloc_free_us']
                                       / out['pool_alloc_free_us'])
    return out


def run(warm: int = 24, steps: int = 64, gen: int = 120,
        out_path: str = 'results/kernel_hotpath.json',
        bench_path: str = 'BENCH_kernels.json') -> Dict:
    variants = {
        'baseline': _measure_decode(False, False, warm=warm, steps=steps,
                                    gen=gen),
        'fused': _measure_decode(True, False, warm=warm, steps=steps,
                                 gen=gen),
        'fused_shared': _measure_decode(True, True, warm=warm, steps=steps,
                                        gen=gen),
    }
    outs: List = [v.pop('_outputs') for v in variants.values()]
    # speed claims only count with identical greedy output
    assert outs[0] == outs[1] == outs[2], \
        'fused/prefix-shared drain diverged from baseline'
    mi = _alloc_micro()
    base = variants['baseline']['us_per_decode_step']
    result = {
        'decode': variants,
        'fused_speedup_x': base / variants['fused']['us_per_decode_step'],
        'fused_shared_speedup_x':
            base / variants['fused_shared']['us_per_decode_step'],
        'alloc': mi,
        'smoke_gates': {'committed_min_speedup_x': 1.0 + SMOKE_GATE,
                        'live_min_speedup_x': SMOKE_MIN_SPEEDUP},
    }
    os.makedirs(os.path.dirname(out_path) or '.', exist_ok=True)
    for path in (out_path, bench_path):
        with open(path, 'w') as f:
            json.dump(result, f, indent=1)
    for name, v in variants.items():
        print(f"{name:13s} {v['us_per_decode_step']:8.0f} us/step  "
              f"{v['decode_tokens_per_s']:7.1f} tok/s  "
              f"(page reads deduped: {v['shared_page_reads_saved']}, "
              f"token flushes: {v['token_flushes']})")
    print(f"session alloc+free {mi['session_alloc_free_us']:.2f}us "
          f"({mi['session_alloc_overhead_x']:.2f}x raw pool)")
    return result


def smoke(baseline_path: str = 'BENCH_kernels.json') -> None:
    """CI regression gate, two checks (raises, not assert, so the gate
    holds under ``-O``):

    1. the *committed* trajectory still claims a real fused win
       (``fused_speedup_x > 1 + SMOKE_GATE`` — catches someone committing
       numbers that quietly lost the speedup);
    2. a quick live re-measure — baseline and fused in the SAME short
       window, so the comparison self-calibrates for machine speed *and*
       window length (the fused advantage grows with window size as the
       single lazy-token flush amortizes, so short-window numbers must
       never be compared against the committed long-window ones) — keeps
       ``SMOKE_MIN_SPEEDUP×``.
    """
    with open(baseline_path) as f:
        committed = json.load(f)
    if committed['fused_speedup_x'] <= 1.0 + SMOKE_GATE:
        raise RuntimeError(
            f"committed BENCH_kernels.json fused_speedup_x "
            f"{committed['fused_speedup_x']:.2f} <= {1 + SMOKE_GATE:.2f} — "
            "the trajectory no longer shows the fused win")
    base = _measure_decode(False, False, warm=12, steps=24, gen=64)
    fused = _measure_decode(True, False, warm=12, steps=24, gen=64)
    speedup = (base['us_per_decode_step'] / fused['us_per_decode_step'])
    print(f"smoke: fused {fused['us_per_decode_step']:.0f} vs baseline "
          f"{base['us_per_decode_step']:.0f} us/step — {speedup:.2f}x live "
          f"(floor {SMOKE_MIN_SPEEDUP:.2f}x; committed long-window "
          f"{committed['fused_speedup_x']:.2f}x)")
    if speedup < SMOKE_MIN_SPEEDUP:
        raise RuntimeError(
            f'fused decode step only {speedup:.2f}x baseline in the smoke '
            f'window (floor: {SMOKE_MIN_SPEEDUP:.2f}x) — the fused win '
            'regressed')


if __name__ == '__main__':
    import sys
    if '--smoke' in sys.argv:
        smoke()
    else:
        run()
