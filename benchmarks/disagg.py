"""Disaggregated vs colocated serving: same trace, hard correctness gates.

One online trace (staggered submissions, fixed per-step virtual time) plus
an equal offline backfill demand, driven through two topologies:

1. **colocated** — a single Valve node: one pool, one online engine, two
   offline engines (the PR-5..8 serving plane);
2. **disagg** — a :class:`~repro.serving.disagg.DisaggPlane`: prefill and
   decode nodes over separate pools, each with its own offline engine,
   joined by migration-based KV handoff.

Virtual time advances a fixed ``dt`` per plane step, so TTFT/TPOT are
deterministic step counts in disguise — differences between the two
topologies are attributable, not noise.

Hard gates (raise, not assert — they must hold under ``-O``), enforced
here and by ``scripts/ci.sh --smoke``:

- **bit identity**: every online request's token sequence is identical
  between the two topologies (greedy decode diverges on any lost or
  wrongly-resumed KV, so equality is the end-to-end witness);
- **zero recompute at handoff**: every online request hands off exactly
  once, and no prefilled token is ever computed again — the telemetry
  fold, the decode engine counter, and each request's ``recomputes`` all
  read 0;
- **joint preemption bound**: every runtime (colocated, prefill, decode)
  reports ``max_preemptions_per_request ≤ 1`` — the paper's bound holds
  per (request, device) across the split.

Reported (the trajectory): TTFT/TPOT p50/p99 per topology, offline
backfill tokens, handoff count/pages/latency, and the interference ratios
(disagg ÷ colocated) for the online tail latencies.

Writes ``results/disagg.json`` and mirrors ``BENCH_disagg.json`` at the
repo root.  ``--smoke`` shrinks the trace and writes under ``results/``.
"""
from __future__ import annotations

import json
import os
from typing import Dict, List

import numpy as np

ARCH = 'qwen3-0.6b'
DT = 1e-3                   # virtual seconds per plane step


def _ecfg(klass):
    from repro.serving.engine import EngineConfig
    return EngineConfig(max_batch=4, max_seq=48, prefill_chunk=8,
                        klass=klass)


def _prompt(vocab, n, seed):
    return np.random.default_rng(seed).integers(1, vocab, n).tolist()


def _node(pool, clock, *, disaggregated, n_offline_engines, prefix):
    from repro.configs import get_config, reduced
    from repro.core.runtime import RuntimeConfig, ValveRuntime
    from repro.launch.node import NodeOrchestrator
    rt = ValveRuntime(pool, RuntimeConfig(n_devices=1, t_cool_init=0.002),
                      clock=clock)
    node = NodeOrchestrator(rt, idle_advance=1e-3,
                            disaggregated=disaggregated)
    cfg = reduced(get_config(ARCH), page_size=4)
    node.add_engine(cfg, _ecfg('online'), seed=0, name=f'{prefix}online')
    for i in range(n_offline_engines):
        node.add_engine(cfg, _ecfg('offline'), seed=0,
                        name=f'{prefix}off{i}')
    return node


def _mk_colocated():
    from repro.core.clock import VirtualClock
    from repro.serving.kvpool import KVPool
    return _node(KVPool(10, 4, page_size=4, reserved_handles=5,
                        name='colo'),
                 VirtualClock(), disaggregated=False,
                 n_offline_engines=2, prefix='')


def _mk_disagg():
    from repro.core.clock import VirtualClock
    from repro.serving.disagg import DisaggPlane
    from repro.serving.kvpool import KVPool
    clock = VirtualClock()
    prefill = _node(KVPool(10, 4, page_size=4, reserved_handles=5,
                           name='prefill'),
                    clock, disaggregated=True, n_offline_engines=1,
                    prefix='p-')
    decode = _node(KVPool(10, 4, page_size=4, reserved_handles=7,
                          name='decode'),
                   clock, disaggregated=True, n_offline_engines=1,
                   prefix='d-')
    return DisaggPlane(prefill, decode)


def _drive(target, *, n_online: int, gap: int, n_offline: int,
           max_steps: int = 200_000):
    """Replay the shared trace: offline backlog first (round-robin over
    the target's offline engines), then one online request every ``gap``
    steps; the clock advances DT per step."""
    clock = target.clock
    vocab = target.online.mcfg.vocab_size
    offline = list(target.offline)
    off = [(offline[i % len(offline)],
            offline[i % len(offline)].submit(_prompt(vocab, 8, 200 + i),
                                             max_new_tokens=8))
           for i in range(n_offline)]
    for _ in range(4):                      # offline decode under way
        clock.advance(DT)
        target.step()
    rids: List[str] = []
    for step in range(max_steps):
        if len(rids) < n_online and step % gap == 0:
            rids.append(target.online.submit(
                _prompt(vocab, 12, 40 + len(rids)), max_new_tokens=8))
        clock.advance(DT)
        target.step()
        if len(rids) == n_online and not target.has_work():
            break
    if target.has_work():
        raise RuntimeError(f'trace did not drain in {max_steps} steps')
    return rids, off


def _pct(xs, q):
    return float(np.percentile(xs, q)) if xs else None


def _measure(target, label: str, *, n_online, gap, n_offline) -> Dict:
    rids, off = _drive(target, n_online=n_online, gap=gap,
                       n_offline=n_offline)
    reqs = [target.engine_of(r).requests[r] for r in rids]
    outs = [target.engine_of(r).output_tokens(r) for r in rids]
    for eng, rid in off:
        if len(eng.output_tokens(rid)) != 8:
            raise RuntimeError(f'{label}: offline {rid} incomplete')
    ttfts = [r.ttft for r in reqs if r.ttft is not None]
    tpots = [r.tpot for r in reqs if r.tpot and r.tpot > 0]
    res = {
        'online_finished': len(rids),
        'ttft_p50_ms': _pct(ttfts, 50) * 1e3,
        'ttft_p99_ms': _pct(ttfts, 99) * 1e3,
        'tpot_p50_ms': _pct(tpots, 50) * 1e3,
        'tpot_p99_ms': _pct(tpots, 99) * 1e3,
        'offline_tokens': sum(e.stats.tokens_generated
                              for e in target.offline),
        'online_recomputes': sum(r.recomputes for r in reqs),
        '_outputs': outs,
    }
    return res


def run(*, n_online: int = 8, gap: int = 6, n_offline: int = 4,
        out_path: str = 'results/disagg.json',
        bench_path: str = 'BENCH_disagg.json') -> Dict:
    colo = _mk_colocated()
    c = _measure(colo, 'colocated', n_online=n_online, gap=gap,
                 n_offline=n_offline)
    colo.runtime.check_invariants()

    plane = _mk_disagg()
    d = _measure(plane, 'disagg', n_online=n_online, gap=gap,
                 n_offline=n_offline)
    plane.check_invariants()

    # ---- hard gate 1: bit identity across the topologies -------------
    if d.pop('_outputs') != c.pop('_outputs'):
        raise RuntimeError(
            'disagg online outputs diverged from colocated (same trace, '
            'same seed) — the handoff corrupted or lost KV state')

    # ---- hard gate 2: every request handed off, nothing recomputed ---
    if plane.stats.handoffs != n_online:
        raise RuntimeError(
            f'{plane.stats.handoffs}/{n_online} requests handed off '
            f'({plane.stats.handoffs_deferred} deferred) — the decode '
            f'pool must be provisioned to accept every prefill')
    recomputed = plane.decode.online.stats.tokens_recomputed
    tel_p = plane.prefill.runtime.telemetry.snapshot()
    tel_d = plane.decode.runtime.telemetry.snapshot()
    for side, tel in (('prefill', tel_p), ('decode', tel_d)):
        if tel['handoff_recompute_tokens'] != 0:
            raise RuntimeError(
                f"{side} telemetry charged "
                f"{tel['handoff_recompute_tokens']} recomputed handoff "
                f"tokens (contract: 0)")
    if recomputed != 0 or d['online_recomputes'] != 0:
        raise RuntimeError(
            f'handoff recompute != 0 (engine={recomputed}, '
            f"requests={d['online_recomputes']})")

    # ---- hard gate 3: joint preemption bound -------------------------
    bounds = {
        'colocated':
            colo.runtime.telemetry.snapshot()['max_preemptions_per_request'],
        'prefill': tel_p['max_preemptions_per_request'],
        'decode': tel_d['max_preemptions_per_request'],
    }
    for side, b in bounds.items():
        if b > 1:
            raise RuntimeError(
                f'{side}: max_preemptions_per_request {b} > 1 '
                f'(§4.2 joint bound violated)')

    d.update(
        handoffs=plane.stats.handoffs,
        handoffs_deferred=plane.stats.handoffs_deferred,
        pages_copied=plane.stats.pages_copied,
        handoff_latency_ms={
            k: (v * 1e3 if isinstance(v, float) else v)
            for k, v in tel_p['handoff_latency'].items()},
        handoff_recompute_tokens=0)
    interference = {
        'ttft_p99_ratio': d['ttft_p99_ms'] / c['ttft_p99_ms'],
        'tpot_p99_ratio': d['tpot_p99_ms'] / c['tpot_p99_ms'],
    }
    for tag, r in (('colocated', c), ('disagg   ', d)):
        print(f"{tag}: ttft p50/p99 = {r['ttft_p50_ms']:6.2f}/"
              f"{r['ttft_p99_ms']:6.2f} ms  tpot p50/p99 = "
              f"{r['tpot_p50_ms']:5.2f}/{r['tpot_p99_ms']:5.2f} ms  "
              f"offline={r['offline_tokens']} tok")
    print(f"handoffs={d['handoffs']} (deferred {d['handoffs_deferred']})  "
          f"pages={d['pages_copied']}  recompute=0  "
          f"preempt_bound={max(bounds.values())}")

    result = {
        'trace': {'n_online': n_online, 'gap_steps': gap,
                  'n_offline': n_offline, 'dt_s': DT, 'arch': ARCH},
        'colocated': c,
        'disagg': d,
        'interference': interference,
        'gates': {'bit_identical': True,
                  'handoff_recompute_tokens': 0,
                  'max_preemptions_per_request': max(bounds.values())},
    }
    os.makedirs(os.path.dirname(out_path) or '.', exist_ok=True)
    for path in (out_path, bench_path):
        with open(path, 'w') as f:
            json.dump(result, f, indent=1)
    return result


if __name__ == '__main__':
    import sys
    if '--smoke' in sys.argv:
        run(n_online=3, gap=6, n_offline=2,
            out_path='results/disagg_smoke.json',
            bench_path='results/disagg_smoke.json')
        print('disagg smoke OK: bit-identical, zero-recompute handoff, '
              'preemption bound ≤ 1')
    else:
        run()
