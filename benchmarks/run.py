"""Benchmark orchestrator — one experiment per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--fast] [--only NAME]

Writes per-benchmark JSON to results/ and prints each table.  The dry-run
sweep itself (results/dryrun.jsonl) is produced by
``python -m repro.launch.dryrun --sweep``; benchmarks.roofline consumes it.
See benchmarks/README.md for the script ↔ paper-figure map.
"""
from __future__ import annotations

import argparse
import os
import sys
import time
import traceback

os.makedirs('results', exist_ok=True)

BENCHES = [
    ('preemption_latency', 'paper §4.1 — serial vs fan-out gate latency'),
    ('decode_gaps', 'paper Fig. 4 — decode-gap telemetry + T_cool'),
    ('miad_convergence', 'paper §5 — MIAD reclamation-rate convergence'),
    ('eviction_policy', 'paper Fig. 11 — Algorithm 1 vs FIFO'),
    ('colocation_matrix', 'paper Fig. 10 — 10 pairs × 6 strategies'),
    ('cluster_utilization', 'paper Fig. 8/9 — fleet utilization + savings'),
    ('cluster_harvest', 'paper §6–7 — closed-loop NodeSim-telemetry fleet'),
    ('roofline', 'supporting analysis — dry-run roofline table'),
    ('serve_throughput', 'serving plane — batched prefill vs seed + node demo'),
    ('api_overhead', 'control-plane API v1 — session/event hot-path cost'),
    ('prefix_reuse', 'memory plane v1 — prefix sharing + partial-invalidation tax'),
    ('kernel_hotpath', 'kernel hot path — fused sampling + prefix-shared decode step'),
    ('shard_scale', 'multi-device plane — mesh scaling + cross-pool rescue tax'),
    ('disagg', 'disaggregated plane — prefill/decode split vs colocated, '
               'zero-recompute handoff'),
    ('fleet_placement', 'placement plane — global optimizer vs greedy on a '
                        'heterogeneous 100-node fleet + vectorized-sim gate'),
]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument('--only', default=None)
    ap.add_argument('--fast', action='store_true',
                    help='shorter horizons for CI')
    args = ap.parse_args()

    failures = []
    for name, desc in BENCHES:
        if args.only and name != args.only:
            continue
        print(f'\n=== {name}: {desc} ===', flush=True)
        t0 = time.time()
        try:
            mod = __import__(f'benchmarks.{name}', fromlist=['run'])
            if args.fast and name == 'colocation_matrix':
                mod.run(n_pairs=4, horizon_s=150.0)
            elif args.fast and name == 'eviction_policy':
                mod.run(horizon_s=150.0)
            elif args.fast and name == 'miad_convergence':
                mod.run(horizon_s=150.0)
            elif args.fast and name == 'serve_throughput':
                mod.run(steps=100)
            elif args.fast and name == 'cluster_harvest':
                mod.run(n_nodes=8, epoch_s=30.0, n_epochs=4)
            elif args.fast and name == 'api_overhead':
                mod.run(horizon_s=60.0)
            elif args.fast and name == 'prefix_reuse':
                mod.run(horizon_s=120.0)
            elif args.fast and name == 'kernel_hotpath':
                mod.run(warm=12, steps=24, gen=64)
            elif args.fast and name == 'shard_scale':
                mod.run(mesh_sizes=(1, 2, 4), warm=12, steps=16, gen=64)
            elif args.fast and name == 'disagg':
                mod.run(n_online=4, gap=6, n_offline=2)
            elif args.fast and name == 'fleet_placement':
                mod.run_smoke()
            else:
                mod.run()
        except Exception:
            traceback.print_exc()
            failures.append(name)
        print(f'--- {name} finished in {time.time() - t0:.1f}s', flush=True)

    if failures:
        print(f'\nFAILED benchmarks: {failures}')
        sys.exit(1)
    print('\nall benchmarks complete; JSON in results/')


if __name__ == '__main__':
    main()
