"""Paper Fig. 4 + §4.2: decode-gap distribution and the T_cool rule.

Runs the live engine (reduced model, CPU) through bursty traffic, collects
the runtime's gap telemetry, and shows T_cool = 2 × max gap separating
intra-request gaps from true idle — the property that bounds preemptions to
one per online request.
"""
from __future__ import annotations

import json
from typing import Dict

import numpy as np


def run(out_path: str = 'results/decode_gaps.json', steps: int = 200) -> Dict:
    from repro.launch.serve import serve_demo
    import jax
    from repro.configs import get_config, reduced as reduce_cfg
    from repro.core.clock import RealClock
    from repro.core.runtime import RuntimeConfig, ValveRuntime
    from repro.models.api import build_model
    from repro.serving.engine import Engine, EngineConfig
    from repro.serving.kvpool import KVPool

    rng = np.random.default_rng(0)
    cfg = reduce_cfg(get_config('qwen3-0.6b'), page_size=4)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    pool = KVPool(16, 8, page_size=4, reserved_handles=2)
    clock = RealClock()
    rt = ValveRuntime(pool, RuntimeConfig(n_devices=1), clock=clock)
    eng = Engine(model, params, pool,
                 EngineConfig(max_batch=8, max_seq=64, prefill_chunk=16,
                              klass='online'),
                 runtime=rt, clock=clock)
    # warm up jit compiles first — compile gaps are not decode gaps
    eng.submit(rng.integers(1, cfg.vocab_size, 12).tolist(),
               max_new_tokens=4)
    for _ in range(30):
        if not eng.step():
            break
    rt.lifecycle._gaps.clear()
    for i in range(6):
        eng.submit(rng.integers(1, cfg.vocab_size, 12).tolist(),
                   max_new_tokens=24)
    for _ in range(steps):
        if not eng.step():
            break
    gaps = np.asarray(rt.lifecycle._gaps)
    result = {
        'n_gaps': int(gaps.size),
        'gap_ms': {
            'p50': float(np.median(gaps) * 1e3) if gaps.size else None,
            'p99': float(np.percentile(gaps, 99) * 1e3) if gaps.size else None,
            'max': float(gaps.max() * 1e3) if gaps.size else None,
        },
        't_cool_ms': rt.lifecycle.t_cool * 1e3,
        'rule': 'T_cool = 2 x max decode gap',
    }
    with open(out_path, 'w') as f:
        json.dump(result, f, indent=1)
    print(f'decode gaps: n={result["n_gaps"]} p50={result["gap_ms"]["p50"]:.3f}ms '
          f'max={result["gap_ms"]["max"]:.3f}ms → T_cool={result["t_cool_ms"]:.3f}ms')
    return result


if __name__ == '__main__':
    run()
