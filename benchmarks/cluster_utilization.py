"""Paper §7.1 / Fig. 8–9: cluster-level utilization improvement and GPU
savings from Valve colocation.

A fleet of 8-GPU nodes runs heterogeneous bursty online services (telemetry
synthesized from the same generators as the node sim); offline jobs —
including multi-GPU model-parallel ones gated by the P_multi ≥ 0.95
alignment rule — are placed by the Eq. 1 scheduler.  Metrics: improved GPU
utilization (fraction of time GPUs run offline compute) and saved GPUs
(Σ normalized offline throughput).  Paper: +34.6 % utilization, 2,170 GPUs
saved on 8,054 (≈ 27 % of fleet).
"""
from __future__ import annotations

import json
from typing import Dict, List, Tuple

import numpy as np

from repro.core.cluster.perfmodel import (GPUTelemetry, NodeTelemetry,
                                          profile_workload)
from repro.core.cluster.scheduler import ClusterScheduler, OfflineJob


def _busy_intervals(rng, horizon: float, duty: float, *,
                    aligned_with=None, align: float = 0.0
                    ) -> List[Tuple[float, float]]:
    """Alternating busy/idle periods with the requested duty cycle; with
    ``aligned_with`` reuse that GPU's intervals for an ``align`` fraction
    (models multi-GPU online services with partial overlap)."""
    if aligned_with is not None and align > 0:
        out = []
        for (a, b) in aligned_with:
            if rng.random() < align:
                out.append((a, b))
            else:
                shift = rng.uniform(0, 30.0)
                out.append((min(a + shift, horizon),
                            min(b + shift, horizon)))
        return out
    out = []
    t = rng.uniform(0, 20.0)
    while t < horizon:
        busy = rng.exponential(20.0 * duty / max(1 - duty, 0.05))
        idle = rng.exponential(20.0)
        out.append((t, min(t + busy, horizon)))
        t += busy + idle
    return out


def make_fleet(n_nodes: int = 64, gpus_per_node: int = 8, *,
               horizon: float = 600.0, seed: int = 0
               ) -> List[NodeTelemetry]:
    rng = np.random.default_rng(seed)
    nodes = []
    total_pages = 4096
    for i in range(n_nodes):
        duty = rng.uniform(0.15, 0.65)       # over-provisioned online
        aligned = rng.random() < 0.68        # paper: 32% partial overlap
        gpus = []
        base_iv = None
        for g in range(gpus_per_node):
            iv = _busy_intervals(rng, horizon, duty,
                                 aligned_with=base_iv,
                                 align=0.97 if aligned else 0.4)
            if base_iv is None:
                base_iv = iv
            ts = np.linspace(0, horizon, 64)
            # free memory dips while busy (online KV), high while idle
            busy_at = np.array([any(a <= t < b for a, b in iv) for t in ts])
            free = np.where(busy_at,
                            rng.uniform(0.2, 0.5) * total_pages,
                            rng.uniform(0.7, 0.95) * total_pages)
            gpus.append(GPUTelemetry(iv, ts, free, window=(0, horizon)))
        nodes.append(NodeTelemetry(f'node{i}', gpus))
    return nodes


def run(out_path: str = 'results/cluster_utilization.json',
        n_nodes: int = 64, seed: int = 0) -> Dict:
    rng = np.random.default_rng(seed + 1)
    nodes = make_fleet(n_nodes, seed=seed)
    sched = ClusterScheduler(nodes)

    jobs = []
    for j in range(n_nodes * 6):
        k = int(rng.choice([1, 1, 1, 1, 2, 4]))   # mostly single-GPU
        prof = profile_workload(
            f'job{j}', thrput_max=1000.0,
            m_req=float(rng.choice([1024, 2048, 3072])), n_gpus=k)
        jobs.append(OfflineJob(prof, sla=float(rng.uniform(0.2, 0.5))))
    placed = 0
    for job in jobs:
        if sched.place(job) is not None:
            placed += 1

    total_gpus = n_nodes * 8
    util_gain = sched.utilization_gain()
    saved = sched.gpus_saved()

    # baseline online-only utilization for the +X% framing
    online_util = float(np.mean([1 - g.idle_fraction()
                                 for n in nodes for g in n.gpus]))
    result = {
        'nodes': n_nodes, 'gpus': total_gpus,
        'jobs_submitted': len(jobs), 'jobs_placed': placed,
        'jobs_pending': len(sched.pending),
        'online_utilization': online_util,
        'utilization_gain': util_gain,
        'gpus_saved': saved,
        'gpus_saved_frac_of_fleet': saved / total_gpus,
    }
    with open(out_path, 'w') as f:
        json.dump(result, f, indent=1)
    print(f'fleet: {total_gpus} GPUs, online util {online_util:.1%}')
    print(f'placed {placed}/{len(jobs)} offline jobs '
          f'(multi-GPU gated by P_multi ≥ 0.95)')
    print(f'utilization gain +{util_gain:.1%} (paper: +34.6%)')
    print(f'GPUs saved: {saved:.0f} ({saved / total_gpus:.1%} of fleet; '
          f'paper: 2170/8054 = 27%)')
    return result


if __name__ == '__main__':
    run()
