"""Multi-device serving plane: mesh scaling + cross-pool rescue economics.

Two trajectories in one file (``BENCH_shard.json``):

1. **Mesh scaling** — the same decode-heavy drain at tensor-parallel mesh
   sizes 1 (``mesh=None``, the untouched single-device path), 2, 4, 8 over
   *virtual* CPU devices (``--xla_force_host_platform_device_count``, the
   ``launch/dryrun.py`` trick).  On virtual devices the numbers measure
   GSPMD partitioning OVERHEAD, not speedup — CPU "devices" share one
   socket, so tokens/s goes *down* with mesh size.  What the trajectory
   pins is (a) the overhead factor staying sane and (b) greedy outputs
   staying bit-identical wherever the partitioning is exact: every mesh
   width that divides ``n_kv_heads`` must not change a single token
   (hard gate).  Wider meshes overshard the kv-head axis — GSPMD
   replicates it and reorders the contraction, and under bf16 a
   near-tied argmax can flip (the same drain in float32 IS bit-identical
   at every width) — so those sizes record ``tokens_until_divergence``
   in the trajectory instead of hard-failing.

2. **Burst recompute tax** — the node-level online burst from
   ``tests/test_node_migration.py`` with cross-pool rescue ON (an
   auxiliary pool registered) vs OFF (PR-5 truncate-and-recompute).
   Hard gates, enforced here and in CI (``--smoke``):

   - rescue ON reclaims with **zero** offline recomputed tokens;
   - recompute(ON) ≤ recompute(OFF) — migration must never cost more
     compute than the truncation it replaces;
   - at least one victim is actually rescued (≥1 cross-pool migration).

Writes ``results/shard_scale.json`` and mirrors ``BENCH_shard.json`` at
the repo root.  ``--smoke`` runs mesh sizes {1, 2} with a short window
plus the full (cheap) rescue comparison.
"""
from __future__ import annotations

import json
import os
import time
from typing import Dict, List, Optional

# must land before the first jax import (see tests/conftest.py)
_FLAG = '--xla_force_host_platform_device_count=8'
if 'xla_force_host_platform_device_count' not in os.environ.get('XLA_FLAGS', ''):
    os.environ['XLA_FLAGS'] = \
        f"{os.environ.get('XLA_FLAGS', '')} {_FLAG}".strip()
os.environ.setdefault('JAX_PLATFORMS', 'cpu')

import numpy as np

ARCH = 'qwen3-0.6b'


def _mesh(n: Optional[int]):
    import jax
    from jax.sharding import Mesh
    if n is None or n == 1:
        return None
    devs = jax.devices()
    if len(devs) < n:
        return None                      # flag ineffective — skip this size
    return Mesh(np.asarray(devs[:n]), ('model',))


def _measure_mesh(n_dev: int, *, warm: int, steps: int, gen: int) -> Optional[Dict]:
    """Steady-state decode µs/step at tensor-parallel width ``n_dev``."""
    import jax
    from repro.configs import get_config, reduced
    from repro.models.api import build_model
    from repro.serving.engine import Engine, EngineConfig
    from repro.serving.kvpool import KVPool

    mesh = _mesh(n_dev)
    if n_dev > 1 and mesh is None:
        return None
    cfg = reduced(get_config(ARCH), page_size=4)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    pool = KVPool(40, 4, page_size=4, reserved_handles=1)
    eng = Engine(model, params, pool,
                 EngineConfig(max_batch=4, max_seq=160, prefill_chunk=16,
                              mesh=mesh))
    rng = np.random.default_rng(0)
    rids = [eng.submit(rng.integers(1, cfg.vocab_size, 24).tolist(),
                       max_new_tokens=gen) for _ in range(4)]
    while (eng.queue
           or any(not eng.requests[r].generated for r in rids)
           or eng.stats.decode_iterations < warm):
        if not eng.step():
            break
    t0 = time.perf_counter()
    for _ in range(steps):
        eng.step()
    eng.flush_tokens()
    wall = time.perf_counter() - t0
    eng.run_to_completion()
    return {
        'mesh_devices': n_dev,
        'us_per_decode_step': wall / steps * 1e6,
        'decode_tokens_per_s': eng.cfg.max_batch / wall * steps,
        '_outputs': [eng.output_tokens(r) for r in rids],
    }


def _burst_node(rescue: bool):
    """The tests/test_node_migration.py scenario, benchmark-sized."""
    from repro.configs import get_config, reduced
    from repro.core.clock import VirtualClock
    from repro.core.runtime import RuntimeConfig, ValveRuntime
    from repro.launch.node import NodeOrchestrator
    from repro.serving.engine import EngineConfig
    from repro.serving.kvpool import KVPool

    def ecfg(klass):
        return EngineConfig(max_batch=4, max_seq=48, prefill_chunk=8,
                            klass=klass)

    pool = KVPool(5, 4, page_size=4, reserved_handles=1, name='poolA')
    rt = ValveRuntime(pool, RuntimeConfig(n_devices=1, t_cool_init=0.002),
                      clock=VirtualClock())
    node = NodeOrchestrator(rt, idle_advance=1e-3)
    cfg = reduced(get_config(ARCH), page_size=4)
    node.add_engine(cfg, ecfg('online'), seed=0, name='online')
    node.add_engine(cfg, ecfg('offline'), seed=0, name='offA')
    if rescue:
        pool_b = node.add_pool(KVPool(8, 4, page_size=4, name='poolB'))
        node.add_engine(cfg, ecfg('offline'), seed=0, name='offB',
                        pool=pool_b)
    return node


def _measure_rescue(rescue: bool) -> Dict:
    node = _burst_node(rescue)
    rng = np.random.default_rng(7)
    eng = node.names['offA']
    for _ in range(2):
        eng.submit(rng.integers(1, eng.mcfg.vocab_size, 12).tolist(),
                   max_new_tokens=8)
    for _ in range(4):
        node.step()
    node.online.submit(
        rng.integers(1, node.online.mcfg.vocab_size, 28).tolist(),
        max_new_tokens=12)
    node.drain(max_steps=5000)
    node.runtime.check_invariants()
    offline_recompute = sum(e.stats.tokens_recomputed for e in node.offline)
    return {
        'rescue_enabled': rescue,
        'reclamations': node.runtime.reclaimer.stats.reclamations,
        'offline_tokens_recomputed': offline_recompute,
        'requests_rescued': node.stats.requests_rescued,
        'pages_migrated':
            node.runtime.telemetry.snapshot()['pages_migrated'],
        'rescued_tokens_recomputed':
            (node.names['offB'].stats.tokens_recomputed if rescue else None),
    }


def run(*, mesh_sizes=(1, 2, 4, 8), warm: int = 24, steps: int = 48,
        gen: int = 120, out_path: str = 'results/shard_scale.json',
        bench_path: str = 'BENCH_shard.json') -> Dict:
    from repro.configs import get_config, reduced
    n_kv = reduced(get_config(ARCH), page_size=4).n_kv_heads
    scaling: List[Dict] = []
    ref_out = None
    for n in mesh_sizes:
        m = _measure_mesh(n, warm=warm, steps=steps, gen=gen)
        if m is None:
            print(f'mesh={n}: skipped (not enough virtual devices)')
            continue
        outs = m.pop('_outputs')
        if ref_out is None:
            ref_out = outs
        divergence = [
            next((i for i, (x, y) in enumerate(zip(a, b)) if x != y), None)
            for a, b in zip(ref_out, outs)]
        m['tokens_until_divergence'] = divergence
        # exact partitioning (width divides the kv-head axis) must not
        # change a single sampled token; oversharded widths may tie-flip
        # under bf16 and only record where
        if n_kv % n == 0 and any(d is not None for d in divergence):
            raise RuntimeError(
                f'mesh={n} drain diverged from mesh=1 at {divergence} '
                f'with exact kv-head partitioning ({n_kv} heads)')
        scaling.append(m)
        print(f"mesh={n}: {m['us_per_decode_step']:8.0f} us/step  "
              f"{m['decode_tokens_per_s']:7.1f} tok/s  "
              f"divergence={divergence}")

    on = _measure_rescue(True)
    off = _measure_rescue(False)
    for tag, r in (('rescue on ', on), ('rescue off', off)):
        print(f"{tag}: recompute={r['offline_tokens_recomputed']:3d} tok  "
              f"rescued={r['requests_rescued']}  "
              f"pages_migrated={r['pages_migrated']}")
    # hard gates (raise, not assert — must hold under -O)
    if on['requests_rescued'] < 1 or on['pages_migrated'] < 1:
        raise RuntimeError('burst rescued no victim cross-pool')
    if on['rescued_tokens_recomputed'] != 0:
        raise RuntimeError(
            f"rescued victims recomputed "
            f"{on['rescued_tokens_recomputed']} tokens (must be 0)")
    if on['offline_tokens_recomputed'] > off['offline_tokens_recomputed']:
        raise RuntimeError(
            f"rescue recompute tax {on['offline_tokens_recomputed']} > "
            f"truncation {off['offline_tokens_recomputed']}")

    result = {
        'mesh_scaling': scaling,
        'note': ('virtual CPU devices: mesh numbers measure GSPMD '
                 'partitioning overhead (expected to slow down); outputs '
                 f'bit-identical for widths dividing n_kv_heads={n_kv}, '
                 'oversharded widths may bf16-tie-flip (f32 is exact) — '
                 'see tokens_until_divergence'),
        'burst_recompute_tax': {'rescue_on': on, 'rescue_off': off},
    }
    os.makedirs(os.path.dirname(out_path) or '.', exist_ok=True)
    for path in (out_path, bench_path):
        with open(path, 'w') as f:
            json.dump(result, f, indent=1)
    return result


if __name__ == '__main__':
    import sys
    if '--smoke' in sys.argv:
        # short window, narrow meshes; full rescue gates (they're cheap)
        run(mesh_sizes=(1, 2), warm=12, steps=16, gen=64,
            out_path='results/shard_scale_smoke.json',
            bench_path='results/shard_scale_smoke.json')
        print('shard_scale smoke OK: mesh parity + zero-recompute rescue')
    else:
        run()
