"""Paper Fig. 11: Valve's selective handle reclamation (Algorithm 1) vs
FIFO, sweeping reclamation rate and reclaimed size.

Metric: offline throughput loss vs the undisturbed run — Algorithm 1 picks
handles tied to the fewest in-flight request tokens, so fewer tokens
recompute.  Paper: 22.9 %–40.1 % lower throughput loss than FIFO.
"""
from __future__ import annotations

import json
from typing import Dict, List

import numpy as np

from repro.core.sim.colocation import (NodeSim, SimConfig,
                                       run_offline_standalone)
from repro.core.sim.strategies import Channel, OurMem
from repro.core.sim.workload import (OnlineRequest, OnlineWorkload,
                                     WorkloadPair, make_workload_pairs)


def _pulse_pair(period_s: float, pages: int, page_tokens: int,
                horizon_s: float, hold_s: float = 4.0) -> WorkloadPair:
    """Online trace that periodically allocates a burst of ``pages`` and
    releases it — a pure memory-reclamation driver (7B-vs-7B colocation as
    in the paper's Fig. 11 setup).

    The offline side mixes request sizes so pool handles end up holding
    different numbers of in-flight requests — the fragmentation Algorithm 1
    exploits (uniform sizes make every handle look identical and the policy
    choice moot)."""
    from repro.core.sim.workload import OfflineWorkload
    reqs: List[OnlineRequest] = []
    t = 10.0
    i = 0
    tokens = pages * page_tokens
    while t < horizon_s - hold_s:
        # one request whose prompt occupies the pages and decodes shortly
        reqs.append(OnlineRequest(f'pulse-{i}', t, tokens, 8))
        t += period_s
        i += 1
    offline = OfflineWorkload(
        'mixed-offline', prompt_tokens=1024, output_tokens=192,
        max_batch=48,
        prompt_choices=(128, 256, 512, 1024, 2048, 4096),
        output_choices=(32, 64, 128, 256, 512), seed=1)
    return WorkloadPair('pulse', OnlineWorkload('pulse', reqs, horizon_s),
                        offline)


def run(out_path: str = 'results/eviction_policy.json',
        horizon_s: float = 240.0) -> Dict:
    cfg = SimConfig()
    rows = []
    base_pair = _pulse_pair(30.0, 512, cfg.page_tokens, horizon_s)
    ref = run_offline_standalone(base_pair, cfg).offline_throughput

    for sweep, values in (('rate', [60.0, 30.0, 15.0, 8.0]),
                          ('size', [256, 512, 1024, 1536])):
        for v in values:
            period = v if sweep == 'rate' else 30.0
            pages = 512 if sweep == 'rate' else v
            pair = _pulse_pair(period, pages, cfg.page_tokens, horizon_s)
            out = {}
            for policy in ('valve', 'fifo'):
                mp = OurMem(cfg.total_pages, cfg.page_tokens, policy=policy)
                r = NodeSim(pair, Channel(), mp, cfg).run()
                out[policy] = {
                    'thrput': r.offline_throughput,
                    'loss': max(0.0, 1 - r.offline_throughput / ref),
                    'recompute_tokens': r.recompute_tokens,
                }
            lv, lf = out['valve']['loss'], out['fifo']['loss']
            rows.append({
                'sweep': sweep, 'value': v,
                'valve': out['valve'], 'fifo': out['fifo'],
                'loss_reduction_pct': (1 - lv / lf) * 100 if lf > 0 else 0.0,
            })
            print(f"[eviction] {sweep}={v}: loss valve {lv:.3f} vs fifo "
                  f"{lf:.3f} (-{rows[-1]['loss_reduction_pct']:.1f}%)",
                  flush=True)

    reductions = [r['loss_reduction_pct'] for r in rows
                  if r['fifo']['loss'] > 0.01]
    result = {'rows': rows, 'reference_thrput': ref,
              'loss_reduction_range_pct': [min(reductions), max(reductions)]
              if reductions else None}
    with open(out_path, 'w') as f:
        json.dump(result, f, indent=1)
    if reductions:
        print(f'throughput-loss reduction vs FIFO: '
              f'{min(reductions):.1f}%–{max(reductions):.1f}% '
              f'(paper: 22.9%–40.1%)')
    return result


if __name__ == '__main__':
    run()
