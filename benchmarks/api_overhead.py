"""Control-plane hot-path overhead — the cost of API v1's indirection.

The session layer and the typed event stream sit on the serving hot path
(admission, iteration notifications, preemption/reclamation), so the
redesign carries a perf contract:

1. **micro**: per-call cost of session alloc/free, the admit/finish
   bundles, and iteration notifications vs the pre-API direct calls
   (raw pool / direct runtime methods), plus the cost of one event
   publish through the bus with the telemetry registry subscribed;
2. **macro**: ``NodeSim`` smoke wall time with the event bus on vs off
   (``events=False`` is the pre-API baseline) — the bus must add
   **< 10 %** aggregate (hard gate; ``run()`` raises otherwise).  The
   smoke is the first three production-shaped workload pairs (memory- and
   compute-bursty mix) at the cluster harness's default pool size, so the
   gate measures the fleet-scale configuration, not one pathological
   pressure loop.

Writes ``results/api_overhead.json`` and mirrors it to ``BENCH_api.json``
at the repo root (the perf-trajectory record).
"""
from __future__ import annotations

import json
import os
import time
from typing import Dict

from repro.core.clock import VirtualClock
from repro.core.events import EventBus, PreemptionEvent
from repro.core.runtime import RuntimeConfig, ValveRuntime
from repro.core.sim.colocation import NodeSim, SimConfig
from repro.core.sim.strategies import Channel, OurMem
from repro.core.sim.workload import make_workload_pairs
from repro.core.telemetry import TelemetryRegistry
from repro.serving.kvpool import KVPool

MACRO_GATE = 0.10                    # event bus may add <10% to NodeSim


def _time_per_call(fn, n: int, repeats: int = 5) -> float:
    """Best-of-``repeats`` seconds per call of ``fn`` over ``n`` iters."""
    best = float('inf')
    for _ in range(repeats):
        t0 = time.perf_counter()
        for _ in range(n):
            fn()
        best = min(best, time.perf_counter() - t0)
    return best / n


def micro() -> Dict[str, float]:
    n = 20_000

    # -- alloc/free: raw pool vs session vs legacy shim ------------------
    pool = KVPool(n_handles=8, pages_per_handle=8, reserved_handles=1)

    def pool_alloc_free():
        pool.alloc('r', 2, klass='offline')
        pool.free('r')

    rt = ValveRuntime(KVPool(8, 8, reserved_handles=1),
                      RuntimeConfig(), clock=VirtualClock())
    sess = rt.open_session('offline', name='bench')

    def session_alloc_free():
        sess.alloc('r', 2)
        sess.free('r')

    def legacy_alloc_free():
        rt.alloc_offline('r', 2)
        rt.free_offline('r')

    on = rt.open_session('online', name='bench-on')

    def session_admit_finish():
        on.admit('q', 1)
        on.finish('q')

    # -- iteration notifications: direct runtime vs session --------------
    def direct_notify():
        rt.on_online_iteration_start()
        rt.on_online_iteration_end()

    def session_notify():
        on.iteration_start()
        on.iteration_end()

    # -- event dispatch: one publish through bus + registry --------------
    bus = EventBus(VirtualClock())
    TelemetryRegistry(bus)

    def publish_event():
        bus.publish(PreemptionEvent, latency_s=1e-3, requests=('r',))

    out = {
        'pool_alloc_free_us': _time_per_call(pool_alloc_free, n) * 1e6,
        'session_alloc_free_us': _time_per_call(session_alloc_free, n) * 1e6,
        'legacy_shim_alloc_free_us': _time_per_call(legacy_alloc_free, n) * 1e6,
        'session_admit_finish_us': _time_per_call(session_admit_finish, n) * 1e6,
        'direct_notify_us': _time_per_call(direct_notify, n) * 1e6,
        'session_notify_us': _time_per_call(session_notify, n) * 1e6,
        'event_publish_us': _time_per_call(publish_event, n) * 1e6,
    }
    out['session_alloc_overhead_x'] = (out['session_alloc_free_us']
                                       / out['pool_alloc_free_us'])
    out['session_notify_overhead_x'] = (out['session_notify_us']
                                        / out['direct_notify_us'])
    # The deprecated klass-string shims are a veneer over the session path
    # and must not re-enter it (they used to pay the public wrapper twice):
    # a shim call may cost at most timing noise over the session call it
    # wraps.  Explicit raise — this contract must hold under -O too.
    if out['legacy_shim_alloc_free_us'] > \
            out['session_alloc_free_us'] * 1.15:
        raise RuntimeError(
            f"legacy shim alloc+free {out['legacy_shim_alloc_free_us']:.2f}us"
            f" > 1.15x session {out['session_alloc_free_us']:.2f}us — the"
            " shim is double-entering the session path")
    return out


def macro(horizon_s: float = 120.0, repeats: int = 3,
          n_pairs: int = 3) -> Dict[str, object]:
    """NodeSim smoke (Valve strategy) with the event bus on vs off —
    aggregate wall time over the first ``n_pairs`` workload pairs at the
    cluster harness's default pool size (1024 pages)."""
    pairs = make_workload_pairs(n_pairs, horizon_s=horizon_s, seed=3)
    cfg = SimConfig(total_pages=1024)

    def run_once(pair, events: bool):
        sim = NodeSim(pair, Channel(), OurMem(cfg.total_pages,
                                              cfg.page_tokens),
                      cfg, events=events)
        t0 = time.perf_counter()
        res = sim.run()
        return time.perf_counter() - t0, res

    per_pair = []
    base_total = on_total = 0.0
    n_events = 0
    for pair in pairs:
        run_once(pair, True)             # warm allocator/caches per pair
        t_off = min(run_once(pair, False)[0] for _ in range(repeats))
        t_on, res = float('inf'), None
        for _ in range(repeats):
            t1, r = run_once(pair, True)
            if t1 < t_on:
                t_on, res = t1, r
        base_total += t_off
        on_total += t_on
        n_events += len(res.events)
        per_pair.append({'pair': pair.name, 'wall_s_off': t_off,
                         'wall_s_on': t_on, 'events': len(res.events),
                         'overhead_frac': t_on / t_off - 1.0})
    return {
        'nodesim_wall_s_events_off': base_total,
        'nodesim_wall_s_events_on': on_total,
        'events_published': n_events,
        'overhead_frac': on_total / base_total - 1.0,
        'per_pair': per_pair,
    }


def run(out_path: str = 'results/api_overhead.json',
        bench_path: str = 'BENCH_api.json',
        horizon_s: float = 120.0) -> Dict:
    mi = micro()
    ma = macro(horizon_s=horizon_s)
    # explicit raise (not assert): this gate must hold even under -O
    if ma['overhead_frac'] >= MACRO_GATE:
        raise RuntimeError(
            f"event bus adds {ma['overhead_frac']:.1%} to NodeSim wall "
            f"time (gate: <{MACRO_GATE:.0%})")
    result = {'micro': mi, 'macro': ma, 'gate_overhead_max': MACRO_GATE}
    os.makedirs(os.path.dirname(out_path) or '.', exist_ok=True)
    for path in (out_path, bench_path):
        with open(path, 'w') as f:
            json.dump(result, f, indent=1)
    print(f"session alloc+free {mi['session_alloc_free_us']:.2f}us "
          f"(pool {mi['pool_alloc_free_us']:.2f}us, "
          f"{mi['session_alloc_overhead_x']:.2f}x); "
          f"notify {mi['session_notify_us']:.2f}us "
          f"({mi['session_notify_overhead_x']:.2f}x); "
          f"publish {mi['event_publish_us']:.2f}us")
    print(f"NodeSim events on/off: {ma['nodesim_wall_s_events_on']:.3f}s / "
          f"{ma['nodesim_wall_s_events_off']:.3f}s "
          f"(+{ma['overhead_frac']:.1%}, {ma['events_published']} events, "
          f"gate <{MACRO_GATE:.0%})")
    return result


if __name__ == '__main__':
    run()
