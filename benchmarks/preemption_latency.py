"""Paper §4.1: multi-GPU preemption latency — serial (un-patched driver,
one node-wide lock) vs fan-out (the 1-line driver change).

Reproduces the shape of the ">5 ms → <1 ms on 8 GPUs" claim: serial grows
O(#devices), fan-out stays ≈ max over devices.  The per-device op latency
models the KMD ioctl round-trip (0.6 ms, the paper's sub-ms channel
disable).
"""
from __future__ import annotations

import json
import statistics
from typing import Dict, List

from repro.core.gate import DeviceGate, GateGroup

OP_LATENCY_S = 0.6e-3
TRIALS = 30


def measure(mode: str, n_devices: int, trials: int = TRIALS) -> Dict:
    lat: List[float] = []
    for _ in range(trials):
        group = GateGroup([DeviceGate(i, OP_LATENCY_S)
                           for i in range(n_devices)], mode=mode)
        lat.append(group.disable_all())
        group.enable_all()
        group.close()
    return {
        'mode': mode, 'devices': n_devices,
        'p50_ms': statistics.median(lat) * 1e3,
        'max_ms': max(lat) * 1e3,
    }


def run(out_path: str = 'results/preemption_latency.json') -> Dict:
    rows = []
    for n in (1, 2, 4, 8):
        for mode in ('serial', 'fanout'):
            rows.append(measure(mode, n))
    result = {'rows': rows, 'op_latency_ms': OP_LATENCY_S * 1e3}
    with open(out_path, 'w') as f:
        json.dump(result, f, indent=1)
    print(f'{"devices":>8} {"serial p50 (ms)":>16} {"fanout p50 (ms)":>16}')
    by = {(r['mode'], r['devices']): r for r in rows}
    for n in (1, 2, 4, 8):
        print(f'{n:8d} {by[("serial", n)]["p50_ms"]:16.2f} '
              f'{by[("fanout", n)]["p50_ms"]:16.2f}')
    s8 = by[('serial', 8)]['p50_ms']
    f8 = by[('fanout', 8)]['p50_ms']
    print(f'8-GPU preemption: serial {s8:.2f} ms → fanout {f8:.2f} ms '
          f'(paper: >5 ms → <1 ms-class)')
    return result


if __name__ == '__main__':
    run()
