"""Paper Fig. 10: TTFT / TPOT / offline-throughput across 10 production
workload pairs × 6 colocation strategies.

Per pair and strategy we report the MEAN TTFT/TPOT increase vs the online
standalone run and offline throughput normalized to Channel+Prism (the
no-memory-preemption bound, as the paper normalizes).  The headline claims
this reproduces: Valve < 5 % TTFT and < 2 % TPOT increase across
workloads, at ≈ Channel+Prism offline throughput.
"""
from __future__ import annotations

import json
from typing import Dict, List

import numpy as np

from repro.core.sim.colocation import (SimConfig, run_online_standalone,
                                       run_strategy)
from repro.core.sim.strategies import STRATEGIES
from repro.core.sim.workload import make_workload_pairs


def _pct_increase(new: Dict[str, float], base: Dict[str, float]) -> float:
    vals = [(new[k] - base[k]) / max(base[k], 1e-9) * 100.0
            for k in base if k in new]
    return float(np.mean(vals)) if vals else 0.0


def run(out_path: str = 'results/colocation_matrix.json',
        n_pairs: int = 10, horizon_s: float = 300.0) -> Dict:
    cfg = SimConfig()
    pairs = make_workload_pairs(n_pairs, horizon_s=horizon_s)
    rows: List[Dict] = []
    for pair in pairs:
        base = run_online_standalone(pair, cfg)
        prism = run_strategy(pair, 'Channel', 'Prism', cfg)
        for cpn, mpn in STRATEGIES:
            r = (prism if (cpn, mpn) == ('Channel', 'Prism')
                 else run_strategy(pair, cpn, mpn, cfg))
            rows.append({
                'pair': pair.name,
                'memory_bursty': pair.memory_bursty,
                'strategy': f'{cpn}+{mpn}',
                'ttft_increase_pct': _pct_increase(r.ttft, base.ttft),
                'tpot_increase_pct': _pct_increase(r.tpot, base.tpot),
                'offline_norm': r.offline_throughput
                / max(prism.offline_throughput, 1e-9),
                'preemptions': r.compute_stats.preemptions,
                'max_preempt_per_request': r.max_preempt_per_request,
                'recompute_tokens': r.recompute_tokens,
            })
        print(f'[colocation] {pair.name} done', flush=True)

    # aggregate per strategy
    summary = {}
    for cpn, mpn in STRATEGIES:
        s = f'{cpn}+{mpn}'
        sel = [r for r in rows if r['strategy'] == s]
        summary[s] = {
            'ttft_increase_pct_mean': float(np.mean(
                [r['ttft_increase_pct'] for r in sel])),
            'ttft_increase_pct_max': float(np.max(
                [r['ttft_increase_pct'] for r in sel])),
            'tpot_increase_pct_mean': float(np.mean(
                [r['tpot_increase_pct'] for r in sel])),
            'tpot_increase_pct_max': float(np.max(
                [r['tpot_increase_pct'] for r in sel])),
            'offline_norm_mean': float(np.mean(
                [r['offline_norm'] for r in sel])),
            'max_preempt_per_request': int(np.max(
                [r['max_preempt_per_request'] for r in sel])),
        }
    result = {'rows': rows, 'summary': summary}
    with open(out_path, 'w') as f:
        json.dump(result, f, indent=1)

    print(f'{"strategy":24s} {"dTTFT%":>8} {"dTPOT%":>8} {"off(norm)":>10} '
          f'{"maxPre/req":>10}')
    for s, v in summary.items():
        print(f'{s:24s} {v["ttft_increase_pct_mean"]:8.1f} '
              f'{v["tpot_increase_pct_mean"]:8.1f} '
              f'{v["offline_norm_mean"]:10.2f} '
              f'{v["max_preempt_per_request"]:10d}')
    valve = summary['Channel+OurMem']
    print(f"Valve: TTFT +{valve['ttft_increase_pct_mean']:.1f}% "
          f"TPOT +{valve['tpot_increase_pct_mean']:.1f}% "
          f"(paper: <5% / <2%), ≤{valve['max_preempt_per_request']} "
          f"preemption/request")
    return result


if __name__ == '__main__':
    run()
