"""Roofline table from the dry-run sweep (supporting analysis — backs the
performance claims rather than reproducing one numbered paper figure).

Reads results/dryrun.jsonl (written by ``python -m repro.launch.dryrun
--sweep``) and renders the per-(arch × shape × mesh) three-term roofline —
compute, HBM, collective — with the dominant bottleneck, the
MODEL_FLOPS/HLO_FLOPS useful ratio, and per-device HBM fit.  Prints a skip
message when the sweep output is absent.  Hardware model: TPU v5e —
197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
"""
from __future__ import annotations

import json
import os
from typing import Dict, List, Optional

HBM_PER_CHIP = 16e9   # v5e


def load(path: str = 'results/dryrun.jsonl') -> List[Dict]:
    rows = []
    if not os.path.exists(path):
        return rows
    seen = {}
    with open(path) as f:
        for line in f:
            try:
                r = json.loads(line)
            except json.JSONDecodeError:
                continue
            key = (r.get('arch'), r.get('shape'), r.get('mesh'),
                   r.get('rules_variant', 'default'),
                   r.get('microbatches', 1))
            seen[key] = r   # latest record wins
    return list(seen.values())


def table(rows: List[Dict], mesh: str = 'single',
          variant: str = 'default') -> str:
    out = [f'| arch | shape | compute_s | memory_s | collective_s | '
           f'dominant | useful | HBM GB (peak/dev) |',
           '|---|---|---|---|---|---|---|---|']
    sel = sorted((r for r in rows
                  if r.get('mesh') == mesh
                  and r.get('rules_variant', 'default') == variant),
                 key=lambda r: (r['arch'], r['shape']))
    for r in sel:
        if r.get('status') != 'ok':
            out.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                       f"{r.get('status')} | — | — |")
            continue
        rf = r['roofline']
        out.append(
            f"| {r['arch']} | {r['shape']} | {rf['compute_s']:.4f} | "
            f"{rf['memory_s']:.4f} | {rf['collective_s']:.4f} | "
            f"{rf['dominant']} | {r['useful_flops_ratio']:.3f} | "
            f"{r['hbm']['peak'] / 1e9:.2f} |")
    return '\n'.join(out)


def summarize(rows: List[Dict]) -> Dict:
    ok = [r for r in rows if r.get('status') == 'ok'
          and r.get('rules_variant', 'default') == 'default'
          and r.get('microbatches', 1) == 1]
    skipped = [r for r in rows if str(r.get('status', '')).startswith('skip')]
    doms: Dict[str, int] = {}
    worst = None
    most_coll = None
    for r in ok:
        rf = r['roofline']
        doms[rf['dominant']] = doms.get(rf['dominant'], 0) + 1
        terms = [rf['compute_s'], rf['memory_s'], rf['collective_s']]
        frac = rf['compute_s'] / max(max(terms), 1e-12)
        if worst is None or frac < worst[0]:
            worst = (frac, r['arch'], r['shape'], r['mesh'])
        cshare = rf['collective_s'] / max(sum(terms), 1e-12)
        if most_coll is None or cshare > most_coll[0]:
            most_coll = (cshare, r['arch'], r['shape'], r['mesh'])
    over_hbm = [(r['arch'], r['shape'], r['mesh'],
                 r['hbm']['peak'] / 1e9) for r in ok
                if r['hbm']['peak'] > HBM_PER_CHIP]
    return {'n_ok': len(ok), 'n_skipped': len(skipped),
            'dominant_counts': doms,
            'worst_roofline_fraction': worst,
            'most_collective_bound': most_coll,
            'cells_over_hbm': over_hbm}


def run(out_path: str = 'results/roofline_summary.json') -> Dict:
    rows = load()
    s = summarize(rows)
    with open(out_path, 'w') as f:
        json.dump(s, f, indent=1, default=str)
    print(f"dry-run cells ok={s['n_ok']} skipped={s['n_skipped']}")
    print(f"dominant-term distribution: {s['dominant_counts']}")
    if s['worst_roofline_fraction']:
        frac, a, sh, m = s['worst_roofline_fraction']
        print(f'worst roofline fraction: {a} × {sh} × {m} ({frac:.3f})')
    if s['most_collective_bound']:
        c, a, sh, m = s['most_collective_bound']
        print(f'most collective-bound: {a} × {sh} × {m} '
              f'({c:.0%} of terms sum)')
    if s['cells_over_hbm']:
        print(f"cells exceeding 16 GB/device HBM: {s['cells_over_hbm']}")
    return s


if __name__ == '__main__':
    run()
