"""Memory-plane API v1 — shared-prefix reuse & partial-invalidation tax.

Two experiments, one JSON (HyGen: shared-prefix offline batches are the
dominant harvest workload; ConServe: harvesting lives or dies on cheap
partial recompute):

1. **Engine drain** — a shared-system-prompt offline batch drained through
   the real engine with the prefix index ON vs OFF: greedy outputs must be
   bit-identical, while prefill chunks / steps-to-completion / TTFT (in
   scheduler steps) drop with sharing.
2. **NodeSim burst** — a bursty online trace colocated with a shared-prefix
   offline batch under Channel+OurMem in three memory-plane modes:
   ``valve`` (partial invalidation + sharing), ``no-sharing`` (partial
   only), and ``whole-invalidation`` (the pre-lease baseline: every
   reclamation restarts its victims from token 0).  The acceptance bar:
   recompute tokens under partial invalidation are strictly below the
   whole-invalidation baseline.

Writes ``results/prefix_reuse.json`` and mirrors it to ``BENCH_prefix.json``
at the repo root (the perf-trajectory record).
"""
from __future__ import annotations

import json
import os
from typing import Dict

import numpy as np


# ---------------------------------------------------------------------------
# 1. Engine drain: sharing on vs off
# ---------------------------------------------------------------------------

def _engine_drain(sharing: bool, *, n_reqs: int = 8, prefix_tokens: int = 16,
                  tail_tokens: int = 5, gen: int = 8, seed: int = 0) -> Dict:
    import jax
    from repro.configs import get_config, reduced
    from repro.core.memory import MemoryPlane
    from repro.models.api import build_model
    from repro.serving.engine import Engine, EngineConfig
    from repro.serving.kvpool import KVPool

    cfg = reduced(get_config('qwen3-0.6b'), page_size=4)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(seed))
    pool = KVPool(n_handles=24, pages_per_handle=8, page_size=4,
                  reserved_handles=1)
    MemoryPlane(pool, sharing=sharing)
    eng = Engine(model, params, pool,
                 EngineConfig(max_batch=3, max_seq=48, prefill_chunk=8,
                              klass='offline'))
    rng = np.random.default_rng(seed)
    prefix = rng.integers(1, cfg.vocab_size, prefix_tokens).tolist()
    rids = [eng.submit(prefix
                       + rng.integers(1, cfg.vocab_size, tail_tokens).tolist(),
                       max_new_tokens=gen) for _ in range(n_reqs)]
    ttft_steps: Dict[str, int] = {}
    steps = 0
    while eng.queue or eng.running:
        eng.step()
        steps += 1
        for rid in rids:
            if rid not in ttft_steps and eng.requests[rid].generated:
                ttft_steps[rid] = steps
        assert steps < 10_000
    plane = MemoryPlane.of(pool)
    plane.check_invariants()
    return {
        'sharing': sharing,
        'steps_to_completion': steps,
        'prefill_chunks': eng.stats.prefill_chunks,
        'dispatches': eng.stats.dispatches,
        'ttft_steps_mean': float(np.mean(list(ttft_steps.values()))),
        'shared_pages_attached': plane.stats.shared_pages_attached,
        'shared_tokens_saved': plane.stats.shared_tokens_saved,
        'outputs': [eng.output_tokens(r) for r in rids],
    }


# ---------------------------------------------------------------------------
# 2. NodeSim burst: recompute tax under the three memory-plane modes
# ---------------------------------------------------------------------------

def _sim_burst(mode: str, *, horizon_s: float, seed: int = 0) -> Dict:
    from repro.core.sim.colocation import NodeSim, SimConfig
    from repro.core.sim.strategies import Channel, OurMem
    from repro.core.sim.workload import (OfflineWorkload, WorkloadPair,
                                         make_online_trace)

    flags = {
        'valve': dict(partial=True, sharing=True),
        'no-sharing': dict(partial=True, sharing=False),
        'whole-invalidation': dict(partial=False, sharing=False),
    }[mode]
    # sized so an online burst reclaims a SLICE of the offline residency
    # (tail handles of big shared-prefix requests), not the whole pool —
    # the regime partial invalidation exists for; 16-page handles let one
    # request span several handles so tail cuts leave long survivors
    cfg = SimConfig(total_pages=2048)
    online = make_online_trace(
        name='bursty', horizon_s=horizon_s, base_rate=0.08, burst_rate=3.0,
        burst_every_s=30.0, burst_len_s=6.0, prompt_mean=1024,
        prompt_sigma=0.6, out_mean=48, seed=seed)
    offline = OfflineWorkload('prefix-batch', prompt_tokens=1024,
                              output_tokens=128, max_batch=24,
                              shared_prefix_tokens=512)
    pair = WorkloadPair('prefix-burst', online, offline)
    mp = OurMem(cfg.total_pages, cfg.page_tokens, pages_per_handle=16,
                **flags)
    res = NodeSim(pair, Channel(), mp, cfg).run()
    mp.plane.check_invariants()
    tel = res.telemetry.counters
    return {
        'mode': mode,
        'recompute_tokens': res.recompute_tokens,
        'offline_tokens': res.offline_tokens,
        'offline_throughput': res.offline_throughput,
        'reclamations': tel.reclamations,
        'preemptions': tel.preemptions,
        'ttft_p50': float(np.median(list(res.ttft.values())))
        if res.ttft else None,
        'shared_tokens_saved': mp.plane.stats.shared_tokens_saved,
        'tokens_preserved': mp.plane.stats.tokens_preserved,
        'partial_invalidations': mp.plane.stats.partial_invalidations,
        'invalidations': mp.plane.stats.invalidations,
    }


# ---------------------------------------------------------------------------

def run(horizon_s: float = 240.0) -> Dict:
    print('— engine drain: shared-prefix batch, sharing on vs off —')
    off = _engine_drain(False)
    on = _engine_drain(True)
    assert on['outputs'] == off['outputs'], 'sharing changed greedy outputs'
    assert on['shared_pages_attached'] > 0, 'no pages were ever shared'
    assert on['prefill_chunks'] < off['prefill_chunks']
    for r in (off, on):
        print(f"  sharing={str(r['sharing']):5}  steps={r['steps_to_completion']:4d}  "
              f"prefill_chunks={r['prefill_chunks']:3d}  "
              f"ttft_steps={r['ttft_steps_mean']:.1f}  "
              f"tokens_saved={r['shared_tokens_saved']:.0f}")

    print('— NodeSim burst: recompute tax by memory-plane mode —')
    sims = [_sim_burst(m, horizon_s=horizon_s)
            for m in ('valve', 'no-sharing', 'whole-invalidation')]
    base = next(s for s in sims if s['mode'] == 'whole-invalidation')
    nosh = next(s for s in sims if s['mode'] == 'no-sharing')
    valve = next(s for s in sims if s['mode'] == 'valve')
    assert valve['recompute_tokens'] < base['recompute_tokens'], \
        (valve['recompute_tokens'], base['recompute_tokens'])
    for s in sims:
        print(f"  {s['mode']:18}  recompute={s['recompute_tokens']:8.0f}  "
              f"offline_tok={s['offline_tokens']:8.0f}  "
              f"reclaims={s['reclamations']:3d}  "
              f"preserved={s['tokens_preserved']:.0f}")
    saved = 1.0 - valve['recompute_tokens'] / max(base['recompute_tokens'], 1e-9)
    saved_partial = 1.0 - (nosh['recompute_tokens']
                           / max(base['recompute_tokens'], 1e-9))
    print(f"  → partial invalidation alone cuts the recompute tax by "
          f"{saved_partial:.1%}; with prefix sharing the zero-ref cache "
          f"absorbs the bursts ({saved:.1%} cut, offline tokens "
          f"{valve['offline_tokens'] / max(base['offline_tokens'], 1e-9) - 1:+.1%})")

    out = {
        'engine_drain': {'sharing_off': {k: v for k, v in off.items()
                                         if k != 'outputs'},
                         'sharing_on': {k: v for k, v in on.items()
                                        if k != 'outputs'},
                         'outputs_identical': on['outputs'] == off['outputs']},
        'nodesim_burst': {s['mode']: {k: v for k, v in s.items()
                                      if k != 'mode'} for s in sims},
        'recompute_tax_saved_vs_whole': saved,
        'recompute_tax_saved_partial_only': saved_partial,
    }
    os.makedirs('results', exist_ok=True)
    with open('results/prefix_reuse.json', 'w') as f:
        json.dump(out, f, indent=2)
    with open('BENCH_prefix.json', 'w') as f:
        json.dump(out, f, indent=2)
    print('wrote results/prefix_reuse.json and BENCH_prefix.json')
    return out


if __name__ == '__main__':
    run()
