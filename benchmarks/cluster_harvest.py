"""Paper §6–7 closed loop: the Eq. 1 scheduler run on MEASURED NodeSim
telemetry, epoch by epoch — placement, monitoring, eviction of persistent
SLA violators, rescheduling — for Valve vs two baseline strategies.

Unlike ``cluster_utilization.py`` (which scores a synthetic-telemetry fleet
at one instant), every number here is produced by the closed loop in
``core/cluster/harness.py``: node telemetry (busy intervals, free-memory
traces, multi-GPU alignment) is extracted from real ``NodeSim`` runs, each
workload's memory→throughput profile is measured by sweeping the sim, jobs'
achieved normalized throughput is actual offline tokens over the epoch, and
the fleet contains a non-stationary node (quiet when scouted, hot after)
that forces the eviction/reschedule path.

Strategies:
- ``valve``          — Channel preemption + OurMem (Algorithm 1 victims)
- ``fifo-evict``     — Channel + OurMem with FIFO victim selection
- ``kernelpreempt``  — KernelPreempt (iteration-drain) + UVM (fault + kill)

Metrics per strategy: measured utilization gain and GPUs saved (fraction of
fleet GPU-time given to offline work, from reported achieved throughput),
offline tokens, eviction/reschedule counts, and online TTFT/TPOT deltas vs
each epoch slice run standalone.  Paper headline at production scale:
+34.6 % utilization, 2,170 GPUs saved on 8,054.

Writes ``results/cluster_harvest.json`` and mirrors to ``BENCH_cluster.json``
at the repo root.
"""
from __future__ import annotations

import json
import os
from typing import Dict

import numpy as np

from repro.core.cluster.harness import (
    ClusterHarness, HarnessConfig, make_harness)
from repro.core.sim.colocation import SimConfig

STRATEGIES = {
    'valve': dict(compute='Channel', memory='OurMem',
                  eviction_policy='valve'),
    'fifo-evict': dict(compute='Channel', memory='OurMem',
                       eviction_policy='fifo'),
    'kernelpreempt': dict(compute='KernelPreempt', memory='UVM',
                          eviction_policy='valve'),
}


def _assert_measured_telemetry(h: ClusterHarness) -> None:
    """Acceptance gate: every Eq. 1 input the scheduler scored came out of a
    NodeSim run — no hand-written telemetry anywhere in the loop."""
    for tele in h.scheduler.nodes.values():
        assert tele.gpus, tele.name
        for g in tele.gpus:
            assert g.source == 'nodesim', (tele.name, g.source)
            assert len(g.mem_trace_t) >= 2


def run_strategy_fleet(name: str, *, n_nodes: int, gpus_per_node: int,
                       epoch_s: float, n_epochs: int, seed: int) -> Dict:
    cfg = HarnessConfig(n_nodes=n_nodes, gpus_per_node=gpus_per_node,
                        epoch_s=epoch_s, n_epochs=n_epochs, seed=seed,
                        sim=SimConfig(total_pages=1024),
                        **STRATEGIES[name])
    h = make_harness(cfg)
    reports = h.run()
    _assert_measured_telemetry(h)

    total_gpus = n_nodes * gpus_per_node
    last = reports[-1]
    ttft = [r.ttft_delta for r in reports if r.ttft_delta is not None]
    tpot = [r.tpot_delta for r in reports if r.tpot_delta is not None]
    # online-only utilization: as scouted vs the final epoch's measurement
    # (ramp nodes make these diverge — the drift the monitoring loop tracks)
    online_util_scout = float(np.mean(
        [1.0 - g.idle_fraction()
         for tele in h.scout_telemetry.values() for g in tele.gpus]))
    online_util = float(np.mean(
        [1.0 - g.idle_fraction()
         for tele in h.scheduler.nodes.values() for g in tele.gpus]))
    return {
        'strategy': name,
        'nodes': n_nodes, 'gpus': total_gpus, 'epochs': n_epochs,
        'jobs_submitted': len(h.jobs),
        'jobs_placed_final': len(h.scheduler.placements),
        'jobs_pending_final': len(h.scheduler.pending),
        'online_utilization_scout': online_util_scout,
        'online_utilization': online_util,
        'utilization_gain_final': last.utilization_gain_measured,
        'utilization_gain_mean': float(np.mean(
            [r.utilization_gain_measured for r in reports])),
        'gpus_saved_final': last.gpus_saved_measured,
        'offline_tokens_total': sum(r.offline_tokens for r in reports),
        'recompute_tokens_total': sum(r.recompute_tokens for r in reports),
        'evictions': h.scheduler.evictions,
        'reschedules': h.scheduler.reschedules,
        'ttft_delta_mean': float(np.mean(ttft)) if ttft else None,
        'tpot_delta_mean': float(np.mean(tpot)) if tpot else None,
        'epochs_detail': [
            {'epoch': r.epoch,
             'utilization_gain': r.utilization_gain_measured,
             'evictions': r.evictions_total,
             'reschedules': r.reschedules_total,
             'achieved': r.achieved} for r in reports],
    }


def run(out_path: str = 'results/cluster_harvest.json',
        n_nodes: int = 8, gpus_per_node: int = 2, epoch_s: float = 60.0,
        n_epochs: int = 4, seed: int = 0) -> Dict:
    assert n_nodes >= 8 or n_epochs <= 3, \
        'full runs use a ≥8-node fleet (small fleets are for the CI smoke)'
    rows = {}
    for name in STRATEGIES:
        rows[name] = run_strategy_fleet(
            name, n_nodes=n_nodes, gpus_per_node=gpus_per_node,
            epoch_s=epoch_s, n_epochs=n_epochs, seed=seed)
        r = rows[name]
        pct = lambda v: f'{v:+.1%}' if v is not None else 'n/a'
        print(f'{name:>14}: util gain {r["utilization_gain_final"]:+.1%} '
              f'(mean {r["utilization_gain_mean"]:+.1%}), '
              f'GPUs saved {r["gpus_saved_final"]:.2f}/{r["gpus"]}, '
              f'evict {r["evictions"]} resched {r["reschedules"]}, '
              f'recompute {r["recompute_tokens_total"]:.0f} tok, '
              f'TTFT Δ {pct(r["ttft_delta_mean"])} '
              f'TPOT Δ {pct(r["tpot_delta_mean"])}')

    valve = rows['valve']
    # the closed loop must exercise the monitoring plane end to end
    assert valve['evictions'] >= 1 and valve['reschedules'] >= 1, \
        'closed loop did not evict+reschedule an SLA violator'

    result = {
        'fleet': {'nodes': n_nodes, 'gpus_per_node': gpus_per_node,
                  'epoch_s': epoch_s, 'epochs': n_epochs, 'seed': seed},
        'paper_reference': {'utilization_gain': 0.346,
                            'gpus_saved_frac': 2170 / 8054},
        'strategies': rows,
    }
    os.makedirs(os.path.dirname(out_path) or '.', exist_ok=True)
    with open(out_path, 'w') as f:
        json.dump(result, f, indent=1)
    with open('BENCH_cluster.json', 'w') as f:
        json.dump(result, f, indent=1)
    print(f'valve vs baselines (paper: +34.6% util): '
          f'{valve["utilization_gain_final"]:+.1%} vs '
          f'{rows["fifo-evict"]["utilization_gain_final"]:+.1%} (fifo) / '
          f'{rows["kernelpreempt"]["utilization_gain_final"]:+.1%} '
          f'(kernelpreempt+uvm)')
    return result


if __name__ == '__main__':
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument('--nodes', type=int, default=8)
    ap.add_argument('--gpus-per-node', type=int, default=2)
    ap.add_argument('--epoch-s', type=float, default=60.0)
    ap.add_argument('--epochs', type=int, default=4)
    ap.add_argument('--seed', type=int, default=0)
    a = ap.parse_args()
    run(n_nodes=a.nodes, gpus_per_node=a.gpus_per_node, epoch_s=a.epoch_s,
        n_epochs=a.epochs, seed=a.seed)
