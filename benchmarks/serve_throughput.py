"""Serving-plane throughput — not a paper figure; this benchmark tracks the
repo's own serving trajectory (ROADMAP: every PR makes a hot path measurably
faster or records why not).

Three experiments, one JSON:

1. **batched chunked prefill vs the seed path** — a fixed offline workload
   drained to completion under (a) the seed one-request-at-a-time prefill
   (``max_prefill_reqs=1``, no decode piggyback) and (b) the batch-composition
   scheduler (multi-request budgeted prefill + piggybacked decode).  Greedy
   outputs must be identical; scheduler steps-to-completion must drop.
2. **node demo** — the heterogeneous NodeOrchestrator demo under bursty
   online traffic: online TTFT/TPOT p50, offline tokens/s, dispatches/s.
3. **streaming front-end** — the async HTTP surface under trace-replayed
   load: ≥ 64 concurrent SSE streams (front-loaded arrival burst) with an
   offline batch job backfilling, through the in-process ASGI client (the
   exact server code path minus the socket).  Records requests/s, p50/p99
   TTFT and peak concurrency; hard gates: every stream completes, peak
   concurrency ≥ 64, and the ≤ 1-preemption-per-online-request bound holds.

Writes ``results/serve_throughput.json`` (benchmark convention) and mirrors
it to ``BENCH_serve.json`` at the repo root (the perf-trajectory record).
"""
from __future__ import annotations

import json
import os
import time
from typing import Dict

import numpy as np


def _drain_offline(batched: bool, *, n_reqs: int = 8, prompt: int = 24,
                   gen: int = 16, seed: int = 0) -> Dict:
    """Steps-to-completion for a fixed offline backlog under one scheduler
    configuration (no runtime — pure serving-plane measurement)."""
    import jax
    from repro.configs import get_config, reduced
    from repro.models.api import build_model
    from repro.serving.engine import Engine, EngineConfig
    from repro.serving.kvpool import KVPool

    cfg = reduced(get_config('qwen3-0.6b'), page_size=4)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(seed))
    pool = KVPool(n_handles=24, pages_per_handle=8, page_size=4,
                  reserved_handles=1)
    ecfg = EngineConfig(
        max_batch=8, max_seq=64, prefill_chunk=16,
        max_prefill_reqs=4 if batched else 1,
        piggyback_decode=batched, klass='offline')
    eng = Engine(model, params, pool, ecfg)
    rng = np.random.default_rng(seed)
    rids = [eng.submit(rng.integers(1, cfg.vocab_size, prompt).tolist(),
                       max_new_tokens=gen) for _ in range(n_reqs)]
    t0 = time.monotonic()
    eng.run_to_completion()
    wall = time.monotonic() - t0
    return {
        'steps': eng.stats.steps,
        'dispatches': eng.stats.dispatches,
        'mixed_dispatches': eng.stats.mixed_dispatches,
        'prefill_chunks': eng.stats.prefill_chunks,
        'decode_iterations': eng.stats.decode_iterations,
        'tokens': eng.stats.tokens_generated,
        'wall_s': wall,
        'outputs': [eng.output_tokens(r) for r in rids],
    }


def _streaming_frontend(n_streams: int = 72, max_new: int = 6,
                        horizon_s: float = 2.0, seed: int = 0) -> Dict:
    """Trace-replay the async front-end: every arrival opens a live SSE
    stream through the ASGI app while one batch job backfills offline."""
    import asyncio

    from repro.core.clock import RealClock
    from repro.launch.serve import build_node
    from repro.serving.frontend.app import FrontendApp
    from repro.serving.frontend.driver import AsyncNodeDriver
    from repro.serving.frontend.loadgen import (
        LoadGenerator, TraceEntry, make_online_trace)
    from repro.serving.frontend.testing import ASGIClient

    node = build_node(clock=RealClock())
    # all arrivals in the first 10% of the horizon → peak concurrency is
    # the whole trace (streams outlive the arrival window)
    trace = make_online_trace(n_streams, horizon_s=horizon_s,
                              prompt_len=12, max_new_tokens=max_new,
                              seed=seed, burst_frac=1.0)
    trace.append(TraceEntry(t=0.0, kind='batch', n_requests=6,
                            prompt_len=16, max_new_tokens=12,
                            seed=seed + 500))

    async def scenario():
        async with AsyncNodeDriver(node) as driver:
            client = ASGIClient(FrontendApp(driver))
            gen = LoadGenerator(client, node.clock,
                                vocab_size=node.online.mcfg.vocab_size)
            report = await gen.replay(trace)
            # streams are done; let the pump drain the offline batch
            while node.has_work():
                await asyncio.sleep(1e-3)
            return report

    t0 = time.monotonic()
    report = asyncio.run(scenario())
    wall = time.monotonic() - t0
    node.runtime.check_invariants()
    m = node.metrics()

    if report.completed != n_streams:
        raise RuntimeError(f'streaming front-end dropped requests: '
                           f'{report.completed}/{n_streams} completed')
    if report.peak_concurrent_streams < 64:
        raise RuntimeError(f'peak concurrency {report.peak_concurrent_streams}'
                           f' < 64 — the burst did not overlap')
    if m['max_preemptions_per_request'] > 1:
        raise RuntimeError('preemption bound violated under streaming load')

    out = report.to_dict()
    out.update({
        'wall_s': wall,
        'offline_tokens': m['offline_tokens'],
        'compute_preemptions': m['compute_preemptions'],
        'max_preemptions_per_request': m['max_preemptions_per_request'],
        'cancellations': m['cancellations'],
    })
    return out


def run(steps: int = 200, out_path: str = 'results/serve_throughput.json',
        bench_path: str = 'BENCH_serve.json') -> Dict:
    from repro.launch.serve import serve_demo

    single = _drain_offline(batched=False)
    batched = _drain_offline(batched=True)
    # explicit raises (not assert): these gates must hold even under -O —
    # BENCH_serve.json is the perf-trajectory record the README cites
    if batched['outputs'] != single['outputs']:
        raise RuntimeError('batched scheduler changed greedy outputs')
    for r in (single, batched):
        r.pop('outputs')
    if batched['steps'] >= single['steps']:
        raise RuntimeError(
            f"batched prefill did not reduce steps-to-completion: "
            f"{batched['steps']} vs {single['steps']}")

    t0 = time.monotonic()
    demo = serve_demo(steps=steps, quiet=True)
    demo_wall = time.monotonic() - t0
    total_dispatches = (demo['online_dispatches']
                       + demo['offline_dispatches'])

    streaming = _streaming_frontend()

    result = {
        'prefill_composition': {
            'seed_single_request': single,
            'batched_scheduler': batched,
            'steps_delta': single['steps'] - batched['steps'],
            'steps_reduction_pct': round(
                100.0 * (single['steps'] - batched['steps'])
                / single['steps'], 1),
        },
        'node_demo': {
            'steps': steps,
            'wall_s': demo_wall,
            'online_ttft_p50_s': demo['online_ttft_p50'],
            'online_tpot_p50_s': demo['online_tpot_p50'],
            'offline_tokens': demo['offline_tokens'],
            'offline_tokens_per_s': demo['offline_tokens'] / demo_wall,
            'dispatches_per_s': total_dispatches / demo_wall,
            'compute_preemptions': demo['compute_preemptions'],
            'max_preemptions_per_request':
                demo['max_preemptions_per_request'],
            'engines': demo['engines'],
        },
        'streaming_frontend': streaming,
    }
    os.makedirs(os.path.dirname(out_path) or '.', exist_ok=True)
    for path in (out_path, bench_path):
        with open(path, 'w') as f:
            json.dump(result, f, indent=1)
    pc = result['prefill_composition']
    nd = result['node_demo']
    print(f"batched prefill: {batched['steps']} steps vs seed "
          f"{single['steps']} (-{pc['steps_reduction_pct']}%), "
          f"outputs identical")
    print(f"node demo: ttft_p50={nd['online_ttft_p50_s']}s "
          f"tpot_p50={nd['online_tpot_p50_s']}s "
          f"offline={nd['offline_tokens_per_s']:.1f} tok/s "
          f"dispatches={nd['dispatches_per_s']:.1f}/s")
    sf = result['streaming_frontend']
    print(f"streaming front-end: {sf['completed']} streams "
          f"(peak {sf['peak_concurrent_streams']} concurrent) "
          f"{sf['requests_per_s']:.1f} req/s "
          f"ttft_p50={sf['ttft_p50_s']:.3f}s "
          f"ttft_p99={sf['ttft_p99_s']:.3f}s "
          f"max_preempt/req={sf['max_preemptions_per_request']}")
    return result


if __name__ == '__main__':
    run()
