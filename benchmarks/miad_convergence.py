"""Paper §5: MIAD dynamic reservation drives the reclamation rate to the
user target while returning memory to offline between bursts.

Sweeps the target rate and measures the achieved reclamation rate and the
average offline memory share under a bursty online allocation pattern.
"""
from __future__ import annotations

import json
from typing import Dict

import numpy as np

from repro.core.miad import MIADConfig
from repro.core.sim.colocation import NodeSim, SimConfig
from repro.core.sim.strategies import Channel, OurMem
from repro.core.sim.workload import make_workload_pairs


def run(out_path: str = 'results/miad_convergence.json',
        horizon_s: float = 600.0) -> Dict:
    cfg = SimConfig()
    pair = make_workload_pairs(4, horizon_s=horizon_s)[0]  # memory-bursty
    rows = []
    for target in (0.02, 0.05, 0.1, 0.2, 0.5):
        mp = OurMem(cfg.total_pages, cfg.page_tokens,
                    miad=MIADConfig(t_init=0.5, target_rate=target,
                                    h_max=cfg.total_pages // 64))
        samples = []
        orig = mp.tick
        def tick(now, mp=mp, samples=samples, orig=orig):
            orig(now)
            samples.append((now, len(mp.pool.reserved),
                            mp.pool.free_pages_for('offline')))
        mp.tick = tick
        r = NodeSim(pair, Channel(), mp, cfg).run()
        achieved = mp.stats.reclamations / max(r.horizon, 1e-9)
        off_share = float(np.mean([s[2] for s in samples])) / cfg.total_pages
        rows.append({
            'target_rate': target,
            'achieved_rate': achieved,
            'reclamations': mp.stats.reclamations,
            'offline_free_share_mean': off_share,
            'offline_thrput': r.offline_throughput,
        })
        print(f'[miad] target {target:.2f}/s → achieved '
              f'{achieved:.3f}/s, offline free share '
              f'{off_share:.2f}, off thrpt {r.offline_throughput:.0f}',
              flush=True)
    result = {'rows': rows}
    with open(out_path, 'w') as f:
        json.dump(result, f, indent=1)
    return result


if __name__ == '__main__':
    run()
